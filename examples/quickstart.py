"""Quickstart — the paper's own experiment (§7.3, Table 2) in ~40 lines.

Trains the supervised autoencoder on synthetic classification data under the
bi-level ℓ1,∞ constraint with double descent, and prints accuracy + column
sparsity against the unconstrained baseline.

    PYTHONPATH=src python examples/quickstart.py [--epochs 120] [--radius 1.0]
"""

import argparse
import sys

sys.path.insert(0, "src"); sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.types import ProjectionSpec
from repro.core.masks import sparsity
from repro.data import classification_synthetic
from benchmarks.sae_tables import _accuracy, _train_fn
from repro.models import params as PM, sae
from repro.runtime.double_descent import double_descent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=120)
    ap.add_argument("--radius", type=float, default=1.0)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--features", type=int, default=800)
    args = ap.parse_args()

    x, y, informative = classification_synthetic(
        n_samples=args.samples, n_features=args.features,
        n_informative=64, class_sep=0.8)
    import dataclasses
    cfg = dataclasses.replace(registry.get_arch("sae-paper"),
                              d_model=args.features)
    ntr = int(0.8 * len(x))
    xtr, ytr, xte, yte = x[:ntr], y[:ntr], x[ntr:], y[ntr:]

    init = PM.init_params(sae.template(cfg), jax.random.PRNGKey(0))

    # --- baseline: no constraint
    base = _train_fn(cfg, xtr, ytr, epochs=args.epochs, lr=3e-3)(init, None)
    print(f"baseline        acc={_accuracy(base, cfg, xte, yte):5.1f}%  "
          f"sparsity=0.0%")

    # --- the paper: bi-level l1,inf constraint + double descent
    spec = ProjectionSpec(pattern=r"enc1/w", levels=(("inf", 1), (1, 1)),
                          radius=args.radius, transpose=True)
    fn = _train_fn(cfg, xtr, ytr, epochs=args.epochs, lr=3e-3, spec=spec)
    final, mask, stats = double_descent(init, fn, spec)
    acc = _accuracy(final, cfg, xte, yte)
    sp = float(sparsity(final["enc1"]["w"], axis=1))
    print(f"bilevel_l1inf   acc={acc:5.1f}%  sparsity={sp:.1f}%  "
          f"(eta={args.radius})")
    kept = int((jnp.max(jnp.abs(final['enc1']['w']), axis=1) > 0).sum())
    print(f"features kept: {kept}/{args.features} "
          f"(dataset has {len(informative)} informative)")


if __name__ == "__main__":
    main()
