"""End-to-end serving driver: build a small dense LM, run BATCHED requests
through prefill-free greedy decode (``repro.serving.lm``), and report
tokens/s. This is the e2e ``serve a small model with batched requests``
deliverable (runs in ~1 min on the CPU container).

    PYTHONPATH=src python examples/serve_lm.py [--batch 8] [--new 32]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import registry
from repro.models import params as PM
from repro.serving import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    help="any assigned arch (smoke-scaled for CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    api = models.get(cfg)
    params = PM.init_params(api.template(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    out = lm.generate(params, cfg, prompts, max_new=args.new)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    toks = args.batch * (args.prompt_len + args.new)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new}")
    print(f"generated shape={out.shape} in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s incl. compile)")
    print("sample continuation ids:", np.asarray(out[0, :10]))


if __name__ == "__main__":
    main()
