"""End-to-end training driver: LM + the paper's bi-level l1,inf constraint,
with checkpointing, restart, and structured-sparsity reporting.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

``--preset 100m`` is a ~100M-param dense LM (use on real hardware; the CPU
container should stick to ``tiny``). Kill and re-run with the same --ckpt dir
to watch the fault-tolerant restart resume from the latest checkpoint.
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import registry
from repro.configs.types import ArchConfig, ProjectionSpec, TrainConfig
from repro.data import DataConfig, DataPipeline
from repro.optim.projection_hook import tree_sparsity
from repro.runtime import CheckpointManager
from repro.training import init_state, make_train_step

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                 vocab=512, head_dim=32),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=32000, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--radius", type=float, default=50.0)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(registry.get_arch("granite-3-2b"),
                              name=f"lm-{args.preset}", **PRESETS[args.preset])
    tcfg = TrainConfig(
        microbatch=args.batch, lr=1e-3, total_steps=args.steps, warmup=20,
        param_dtype="float32", master_dtype="", remat=False,
        projection=ProjectionSpec(pattern=r"(w_up|w_gate)",
                                  radius=args.radius, every=1),
        checkpoint_every=50)
    api = models.get(cfg)
    pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                                   global_batch=args.batch,
                                   microbatch=args.batch))
    mgr = CheckpointManager(args.ckpt, keep=2)

    state, manifest = mgr.restore()
    start = 0
    if state is None:
        state = init_state(cfg, tcfg, api, jax.random.PRNGKey(0))
    else:
        start = manifest["step"]
        print(f"[restart] resumed from checkpoint step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg, api, impl="naive"))
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {"tokens": jnp.asarray(pipe.batch(step))}
        state, metrics = step_fn(state, batch)
        if (step + 1) % tcfg.checkpoint_every == 0 or step + 1 == args.steps:
            mgr.save_async(step + 1, state)
        if (step + 1) % 20 == 0:
            print(f"step {step + 1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"lr {float(metrics['lr']):.2e}")
    mgr.wait()
    dt = time.perf_counter() - t0
    print(f"{args.steps - start} steps in {dt:.1f}s")
    for name, sp in tree_sparsity(state["params"], tcfg.projection).items():
        print(f"column sparsity {name}: {float(sp):.1f}%")


if __name__ == "__main__":
    main()
