"""Serving-tier latency benchmark: continuous batching vs bucket-and-wait.

Replays ONE seeded open-loop arrival trace (Poisson interarrivals, a mixed
population of plan keys) against both serving implementations:

* ``flush`` baseline — :class:`ProjectionService` driven by the classic
  bucket-and-wait policy: flush when the queue reaches the bucket size or
  the oldest pending request exceeds the age timeout;
* ``engine`` — :class:`ProjectionEngine` (continuous batching, donation,
  warm pool), same planner backend, same trace.

Per-request latency is arrival → result available to the client (flush
return for the baseline; a collector thread claiming results in submission
order for the engine, which if anything *over*-states engine latency).
Reported: p50/p99 latency (µs) and sustained QPS. The committed artifact
``benchmarks/results/BENCH_serving_latency.json`` pins the p99 ratio
(engine/flush); CI's serving job re-runs the smoke trace and gates the
fresh ratio at ≤1.25× the committed one (DESIGN.md §5 derives why the
ratio, not the absolute p99, is the stable quantity on shared runners).

Also benchmarks the batched-grid serving lowering against the vmap-lifted
per-item kernel on several serving buckets (both interpret-mode Pallas, CPU).
This is the honest form of the kernel-pool comparison: ``method="auto"``
measures interpret-mode kernels orders of magnitude slower than the jnp
backends on CPU, so the batched-grid kernel can only win auto *within the
kernel pool* — the ``auto_winner`` field records that shootout.
"""

from __future__ import annotations

import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from .projections import _time

BILEVEL = (("inf", 1), ("1", 1))

# the trace's plan-key population: (shape, levels, weight) — one hot key,
# one warm, one cold-ish, mirroring mixed production traffic
_KEYS = (
    ((32, 64), BILEVEL, 0.6),
    ((16, 24), (("1", 2),), 0.3),
    ((8, 16), BILEVEL, 0.1),
)


def make_trace(n: int, rate_hz: float, seed: int = 0):
    """Seeded open-loop trace: [(arrival_s, key_idx, payload, radius)]."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    weights = np.asarray([w for _, _, w in _KEYS])
    kidx = rng.choice(len(_KEYS), size=n, p=weights / weights.sum())
    out = []
    for t, k in zip(arrivals, kidx):
        shape = _KEYS[k][0]
        out.append((float(t), int(k),
                    rng.normal(size=shape).astype(np.float32),
                    float(rng.uniform(0.5, 4.0))))
    return out


def _percentiles(lat_s):
    lat_us = np.asarray(lat_s) * 1e6
    return float(np.percentile(lat_us, 50)), float(np.percentile(lat_us, 99))


def _warm_executables(method, max_batch=16):
    """Trace + compile every executable either replay can dispatch through
    (each key, each pow-2 bucket, donated and plain, batch and scalar), so
    the timed open-loop passes measure steady-state serving, not compiles —
    one mid-replay compile would otherwise delay the whole backlog."""
    from repro.core import plan as planmod

    rng = np.random.default_rng(42)
    for shape, levels, _ in _KEYS:
        pb = planmod.make_plan(shape, jnp.float32, list(levels),
                               radius_kind="batch", method=method)
        b = 1
        while b <= max_batch:
            # the exact op-by-op pattern ProjectionService.flush executes:
            # stack b payloads + b radii, batch plan, slice b results out —
            # the stack/slice ops compile per bucket size too
            items = [jnp.asarray(rng.normal(size=shape), jnp.float32)
                     for _ in range(b)]
            radii = [jnp.asarray(1.0, jnp.float32) for _ in range(b)]
            out = pb(jnp.stack(items), jnp.stack(radii))
            jax.block_until_ready([out[i] for i in range(b)])
            b *= 2
        ps = planmod.make_plan(shape, jnp.float32, list(levels),
                               method=method)
        y = jnp.asarray(rng.normal(size=shape), jnp.float32)
        jax.block_until_ready(ps(y, jnp.float32(1.0)))


def _replay_flush(trace, method, bucket=8, max_age_s=0.008):
    """Bucket-and-wait: flush at queue depth >= bucket or oldest pending
    older than max_age_s (the pre-engine serving policy). Latency runs from
    the request's SCHEDULED arrival — when the single-threaded driver falls
    behind (it blocks in flush), that queueing delay is real latency."""
    from repro.serving import ProjectionService

    svc = ProjectionService(method=method)
    arrival = {}
    pending = []
    lat = []

    def flush_now():
        svc.flush()
        done = time.perf_counter()
        for tk in pending:
            jax.block_until_ready(svc.result(tk))
            lat.append(done - arrival[tk])
        pending.clear()

    t0 = time.perf_counter()
    oldest = None
    for t_arr, k, payload, radius in trace:
        now = time.perf_counter()
        if t0 + t_arr > now:
            time.sleep(t0 + t_arr - now)
        shape, levels, _ = _KEYS[k]
        tk = svc.submit(jnp.asarray(payload), list(levels), radius)
        arrival[tk] = t0 + t_arr
        pending.append(tk)
        oldest = oldest if oldest is not None else time.perf_counter()
        if len(pending) >= bucket or \
                time.perf_counter() - oldest > max_age_s:
            flush_now()
            oldest = None
    if pending:
        flush_now()
    wall = time.perf_counter() - t0
    return lat, wall


def _replay_engine(trace, method, max_batch=16):
    """Continuous batching: submit on arrival, a collector thread claims
    results in submission order (claim timestamps — conservative). Latency
    runs from the request's scheduled arrival, same as the baseline."""
    from repro.serving import ProjectionEngine

    lat = []
    tickets: "queue.Queue" = queue.Queue()

    with ProjectionEngine(method=method, max_batch=max_batch,
                          warm_buckets=8) as eng:
        # warm pool traces every pow-2 dispatch path per key up front —
        # the SLO story: cold shapes pay their compiles off the hot path
        for shape, levels, _ in _KEYS:
            eng.prewarm(shape, jnp.float32, list(levels))
        eng.wait_warm(timeout=300.0)

        def collect():
            while True:
                item = tickets.get()
                if item is None:
                    return
                tk, t_sched = item
                jax.block_until_ready(eng.result(tk, timeout=120.0))
                lat.append(time.perf_counter() - t_sched)

        th = threading.Thread(target=collect)
        th.start()
        t0 = time.perf_counter()
        for t_arr, k, payload, radius in trace:
            now = time.perf_counter()
            if t0 + t_arr > now:
                time.sleep(t0 + t_arr - now)
            shape, levels, _ = _KEYS[k]
            tk = eng.submit(jnp.asarray(payload), list(levels), radius)
            tickets.put((tk, t0 + t_arr))
        tickets.put(None)
        th.join()
        wall = time.perf_counter() - t0
    return lat, wall


def _kernel_bucket_shootout(interpret=True):
    """Batched-grid generated kernel vs the vmap-lifted per-item kernel on
    a few serving buckets. One CSV row per bucket — the lowerings trade
    blows (the batch grid wins where it collapses the bucket to one or two
    Pallas dispatches, vmap wins on deep multi-stage designs), so every
    bucket is reported rather than cherry-picking one. Timing is the min of
    three interleaved median-of-9 trials: container CPU contention only
    inflates a trial, so the min is the stable estimator."""
    from repro.kernels import codegen

    rng = np.random.default_rng(7)
    rows = []
    for tag, shape, levels, b in (
            ("64_flat_l1", (64,), (("1", 1),), 16),
            ("16x24_l12", (16, 24), (("1", 2),), 8),
            ("32x64_bilevel", (32, 64), BILEVEL, 16)):
        ys = jnp.asarray(rng.normal(size=(b,) + shape), jnp.float32)
        radii = jnp.asarray(rng.uniform(0.5, 2.0, size=b), jnp.float32)
        batched = codegen.build_batched(shape, levels, jnp.float32,
                                        interpret=interpret, jit=True)
        per_item = codegen.build(shape, levels, jnp.float32,
                                 interpret=interpret)
        vmapped = jax.jit(jax.vmap(per_item, in_axes=(0, 0)))
        np.testing.assert_allclose(batched(ys, radii), vmapped(ys, radii),
                                   atol=1e-4)
        t_batched = min(_time(batched, ys, radii, reps=9, warmup=2)
                        for _ in range(3))
        t_vmap = min(_time(vmapped, ys, radii, reps=9, warmup=2)
                     for _ in range(3))
        winner = "codegen_batch" if t_batched <= t_vmap else "codegen_vmap"
        rows.append(
            (f"serving_kernel_{tag}_b{b}", t_batched,
             f"vmap_us={t_vmap:.1f},ratio={t_batched / t_vmap:.3f},"
             f"auto_winner={winner}"))
    return rows


def serving_sweep(full=False):
    """The ``serving`` benchmark section (BENCH_serving_latency.json)."""
    # rate sits well below both policies' service capacity (~2.1k QPS for
    # the flush driver, ~2.6k for the engine on the container), so measured
    # latency reflects the serving POLICY — bucket-and-wait holds requests
    # until depth 8 or the 8 ms age timeout, continuous batching dispatches
    # on arrival — rather than saturation collapse, which is dominated by
    # container CPU contention and unstable run to run.
    n, rate = (900, 1200.0) if full else (300, 1200.0)
    method = "bisect"  # same planner backend for both sides: the comparison
    #                    isolates the serving policy, not the kernel choice
    trace = make_trace(n, rate, seed=0)

    # compile everything up front, then one short untimed shakeout pass per
    # side — the timed pass measures steady-state serving policy only
    _warm_executables(method)
    _replay_flush(trace[: max(30, n // 5)], method)
    _replay_engine(trace[: max(30, n // 5)], method)

    # best-of-3 timed replays per side, interleaved: container CPU
    # contention only ever inflates latency, so the min-p99 replay is the
    # stable estimator (and interleaving decorrelates slow spells)
    runs_f, runs_e = [], []
    for _ in range(3):
        runs_f.append(_replay_flush(trace, method))
        runs_e.append(_replay_engine(trace, method))
    lat_f, wall_f = min(runs_f, key=lambda r: _percentiles(r[0])[1])
    lat_e, wall_e = min(runs_e, key=lambda r: _percentiles(r[0])[1])
    p50_f, p99_f = _percentiles(lat_f)
    p50_e, p99_e = _percentiles(lat_e)
    ratio = p99_e / p99_f
    rows = [
        ("serving_trace_flush_p50", p50_f,
         f"p99_us={p99_f:.0f},qps={len(lat_f) / wall_f:.0f},n={n},"
         f"policy=bucket8_age8ms"),
        ("serving_trace_engine_p50", p50_e,
         f"p99_us={p99_e:.0f},qps={len(lat_e) / wall_e:.0f},n={n},"
         f"policy=continuous"),
        ("serving_trace_p99_engine_vs_flush", p99_e,
         f"flush_p99_us={p99_f:.0f},ratio={ratio:.3f}"),
    ]
    rows.extend(_kernel_bucket_shootout())
    return rows
