"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,fig2,...]

Prints ``name,us_per_call,derived`` CSV rows (µs medians, steady-state).
Default sizes are scaled for the single-core container; --full uses the
paper's sizes. Roofline/dry-run numbers live in experiments/ (they come from
the AOT pipeline, not this driver).
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig2,fig3,fig4,table1,sae")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    from . import projections, sae_tables

    sections = {
        "fig1": lambda: projections.fig1_radius(full=args.full),
        "fig2": lambda: projections.fig2_size(full=args.full),
        "fig3": lambda: projections.fig3_trilevel(full=args.full),
        "table1": lambda: projections.table1_scaling(full=args.full),
        "fig4": projections.fig4_parallel,
        "sae": lambda: sae_tables.tables(full=args.full),
    }
    print("name,us_per_call,derived")
    for key, fn in sections.items():
        if only and key not in only:
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
