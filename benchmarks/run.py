"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,fig2,...]
                                            [--json-dir DIR] [--no-json]

Prints ``name,us_per_call,derived`` CSV rows (µs medians, steady-state) and,
unless ``--no-json``, writes one machine-readable ``BENCH_<section>.json`` per
section into ``--json-dir`` (default: CWD) — the bench-trajectory artifacts CI
uploads. Default sizes are scaled for the single-core container; --full uses
the paper's sizes. Roofline/dry-run numbers live in experiments/ (they come
from the AOT pipeline, not this driver).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys

# artifact file names per section (the methods sweep seeds the trajectory)
_JSON_NAMES = {
    "fig1": "BENCH_fig1_radius.json",
    "fig2": "BENCH_fig2_size.json",
    "fig3": "BENCH_fig3_trilevel.json",
    "fig4": "BENCH_fig4_parallel.json",
    "table1": "BENCH_table1_scaling.json",
    "methods": "BENCH_projection_methods.json",
    "plan": "BENCH_projection_plan.json",
    "sharded": "BENCH_sharded_multilevel.json",
    "codegen": "BENCH_codegen_kernels.json",
    "sharded_codegen": "BENCH_sharded_codegen.json",
    "serving": "BENCH_serving_latency.json",
    "train": "BENCH_train_step.json",
    "sae": "BENCH_sae_tables.json",
    "sae_factory": "BENCH_sae_factory.json",
    "obs": "BENCH_obs_overhead.json",
}


def _write_json(json_dir: pathlib.Path, section: str, rows, full: bool) -> None:
    import jax

    payload = {
        "section": section,
        "full": full,
        "platform": jax.devices()[0].platform,
        "machine": platform.machine(),
        "rows": [
            {"name": name, "us_per_call": round(us, 1), "derived": derived}
            for name, us, derived in rows
        ],
    }
    path = json_dir / _JSON_NAMES[section]
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {path}", file=sys.stderr)
    # the obs-registry state the section's run left behind (engine/planner
    # counters, latency histograms, ...) — one JSON-lines snapshot per
    # section, next to its BENCH artifact, uploaded by CI with it
    from repro.obs import metrics as obs_metrics

    mpath = json_dir / f"METRICS_{section}.jsonl"
    obs_metrics.get_registry().write_jsonl(mpath)
    print(f"# wrote {mpath}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig2,fig3,fig4,table1,methods,plan,"
                         "sharded,codegen,sharded_codegen,serving,train,sae,"
                         "sae_factory,obs")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<section>.json artifacts")
    ap.add_argument("--no-json", action="store_true",
                    help="CSV to stdout only, no artifact files")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    from . import (obs_overhead, projections, sae_factory, sae_tables,
                   serving_trace, train_step)

    sections = {
        "fig1": lambda: projections.fig1_radius(full=args.full),
        "fig2": lambda: projections.fig2_size(full=args.full),
        "fig3": lambda: projections.fig3_trilevel(full=args.full),
        "table1": lambda: projections.table1_scaling(full=args.full),
        "methods": lambda: projections.methods_sweep(full=args.full),
        "plan": lambda: projections.plan_sweep(full=args.full),
        "sharded": lambda: projections.sharded_sweep(full=args.full),
        "codegen": lambda: projections.codegen_sweep(full=args.full),
        "sharded_codegen":
            lambda: projections.sharded_codegen_sweep(full=args.full),
        "serving": lambda: serving_trace.serving_sweep(full=args.full),
        "train": lambda: train_step.train_sweep(full=args.full),
        "fig4": projections.fig4_parallel,
        "sae": lambda: sae_tables.tables(full=args.full),
        "sae_factory": lambda: sae_factory.factory_sweep(full=args.full),
        "obs": lambda: obs_overhead.obs_sweep(full=args.full),
    }
    unknown = only - set(sections)
    if unknown:
        ap.error(f"unknown section(s) {sorted(unknown)}; pick from {sorted(sections)}")
    json_dir = pathlib.Path(args.json_dir)
    if not args.no_json:
        json_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for key, fn in sections.items():
        if only and key not in only:
            continue
        rows = fn()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        if not args.no_json:
            _write_json(json_dir, key, rows, args.full)


if __name__ == "__main__":
    main()
