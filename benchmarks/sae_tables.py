"""Paper §7.3 SAE experiments (Tables 2–5): accuracy vs structured sparsity
under different projections, with double descent.

Synthetic = make_classification clone (1000×2000, 64 informative, sep 0.8);
Lung-like = log-normal heteroscedastic generator (DESIGN.md §7 — the real
LUNG csv is not redistributable/offline). 80/20 split, 5 methods:
baseline (no projection), exact ℓ1,∞, bi-level ℓ1,∞, bi-level ℓ1,1,
bi-level ℓ1,2. Reported: test accuracy %, column-sparsity % of the first
encoder layer (the paper's metric).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.types import ProjectionSpec
from repro.core import project_l1inf_exact
from repro.core.masks import sparsity
from repro.data import classification_synthetic, lung_like
from repro.models import params as PM, sae
from repro.optim import adamw
from repro.optim.projection_hook import project_tree
from repro.runtime.double_descent import double_descent
from repro.configs.types import TrainConfig


def _train_fn(cfg, xtr, ytr, *, epochs, lr, spec=None, exact_radius=None,
              seed=0, alpha=0.1, constrain=False):
    """Returns train_epochs_fn(params, mask) for double_descent."""
    tcfg = TrainConfig(lr=lr, weight_decay=0.0, grad_clip=0.0, warmup=1,
                       total_steps=epochs, master_dtype="")
    batch = {"x": jnp.asarray(xtr), "y": jnp.asarray(ytr)}

    @jax.jit
    def step(params, opt, mask):
        (loss, _), g = jax.value_and_grad(sae.loss_fn, has_aux=True)(
            params, batch, cfg, alpha=alpha, act="silu")
        if mask is not None:
            g = jax.tree_util.tree_map(lambda a, m_: a * m_, g, mask)
        params, opt, _ = adamw.update(g, opt, params, tcfg)
        if mask is not None:
            params = jax.tree_util.tree_map(lambda p, m_: p * m_, params, mask)
        if constrain and spec is not None:
            params = project_tree(params, spec)
        elif constrain and exact_radius is not None:
            params = dict(params, enc1=dict(
                params["enc1"],
                w=project_l1inf_exact(params["enc1"]["w"].T, exact_radius).T))
        return params, opt, loss

    def train_epochs(params, mask):
        opt = adamw.init(params, tcfg)
        for _ in range(epochs):
            params, opt, loss = step(params, opt, mask)
        return params

    return train_epochs


def _accuracy(params, cfg, x, y):
    z, _ = sae.forward(params, jnp.asarray(x), cfg)
    return float(jnp.mean((jnp.argmax(z, -1) == jnp.asarray(y)).astype(jnp.float32)) * 100)


def run_dataset(name, x, y, *, radius, epochs=150, lr=3e-3, seed=0,
                prefix="sae", rewind=True, only=None):
    """5-method sweep on one dataset; rows ``(prefix_name_method, µs, derived)``.

    ``rewind=False`` runs the no-rewind double-descent ablation (descent #2
    fine-tunes the projected weights); ``only`` restricts to a subset of
    method names. The SAE-factory bench reuses this with ``prefix=
    "sae_factory"`` so its artifact rows don't collide with BENCH_sae_tables.
    """
    cfg_base = registry.get_arch("sae-paper")
    import dataclasses
    cfg = dataclasses.replace(cfg_base, d_model=x.shape[1])
    ntr = int(0.8 * len(x))
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    tr, te = order[:ntr], order[ntr:]
    xtr, ytr, xte, yte = x[tr], y[tr], x[te], y[te]

    methods = {
        "baseline": dict(spec=None),
        "exact_l1inf": dict(exact_radius=radius),
        "bilevel_l1inf": dict(spec=ProjectionSpec(
            pattern=r"enc1/w", levels=(("inf", 1), (1, 1)), radius=radius,
            transpose=True)),
        "bilevel_l11": dict(spec=ProjectionSpec(
            pattern=r"enc1/w", levels=((1, 1), (1, 1)), radius=100 * radius,
            transpose=True)),
        "bilevel_l12": dict(spec=ProjectionSpec(
            pattern=r"enc1/w", levels=((2, 1), (1, 1)), radius=10 * radius,
            transpose=True)),
    }
    rows = []
    for mname, kw in methods.items():
        if only is not None and mname not in only:
            continue
        key = jax.random.PRNGKey(seed)
        init = PM.init_params(sae.template(cfg), key)
        fn = _train_fn(cfg, xtr, ytr, epochs=epochs, lr=lr, **kw)
        t0 = time.perf_counter()
        if mname == "baseline":
            final = fn(init, None)
        else:
            spec = kw.get("spec") or ProjectionSpec(pattern=r"enc1/w",
                                                    radius=radius)
            projector = None
            if "exact_radius" in kw:
                projector = lambda p: dict(p, enc1=dict(
                    p["enc1"],
                    w=project_l1inf_exact(p["enc1"]["w"].T, kw["exact_radius"]).T))
            final, _, _ = double_descent(init, fn, spec, projector=projector,
                                         rewind=rewind)
        dt = time.perf_counter() - t0
        acc = _accuracy(final, cfg, xte, yte)
        sp = float(sparsity(final["enc1"]["w"], axis=1))
        rows.append((f"{prefix}_{name}_{mname}", dt * 1e6,
                     f"acc={acc:.1f}%_colsparsity={sp:.1f}%"))
    return rows


def tables(full=False):
    out = []
    n = 1000 if full else 400
    m = 2000 if full else 600
    x, y, _ = classification_synthetic(n_samples=n, n_features=m,
                                       n_informative=64, class_sep=0.8)
    out += run_dataset("synthetic", x, y, radius=1.0,
                       epochs=150 if full else 80)
    if full:
        xl, yl, _ = lung_like()
        out += run_dataset("lung_like", xl, yl, radius=1.0, epochs=150)
    else:
        xl, yl, _ = lung_like(n_samples=400, n_features=600)
        out += run_dataset("lung_like", xl, yl, radius=1.0, epochs=80)
    return out
