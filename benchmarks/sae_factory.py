"""``--only sae_factory``: the sparse-SAE training factory, end to end.

Four row groups into ``BENCH_sae_factory.json``:

1. Paper §7.3 accuracy-vs-column-sparsity tables (5 methods × synthetic +
   lung-like) at factory-bench sizes — ``run_dataset`` from ``sae_tables``
   with the ``sae_factory_`` prefix so the artifact is self-contained.
2. The no-rewind double-descent ablation (descent #2 fine-tunes projected
   weights instead of rewinding to init) on the bi-level ℓ1,∞ method.
3. The factory pipeline itself at miniature scale: harvest a smoke LM's
   residual stream, train one projected dictionary SAE per seed, report the
   cross-seed MMCS (dictionary-consistency headline) and reconstruction MSE.
4. GSP whole-network sparsification on a forced 8-device host mesh
   (subprocess, like ``projections.sharded_sweep``): every LM weight
   projected per step through the mesh executor; derived carries projected
   leaf count, feasibility, and mean column sparsity.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from repro.data import classification_synthetic, lung_like

from .sae_tables import run_dataset

_GSP_CHILD = r"""
import json, sys, time
import jax
from repro.launch.mesh import make_host_mesh
from repro.training import sae_factory as F

assert jax.device_count() == 8, jax.device_count()
mesh = make_host_mesh(1, 8)
t0 = time.perf_counter()
g = F.gsp_whole_network(mesh=mesh, steps=int(sys.argv[1]))
dt = time.perf_counter() - t0
print("ROWS" + json.dumps([[
    "sae_factory_gsp_8dev", dt * 1e6 / int(sys.argv[1]),
    f"nproj={g['n_projected']}_feasible={int(g['feasible'])}"
    f"_colsparsity={g['mean_col_sparsity']:.1f}%_ndev={g['n_devices']}",
]]))
"""


def _tables_rows(full):
    n = 1000 if full else 240
    m = 2000 if full else 300
    epochs = 150 if full else 40
    rows = []
    x, y, _ = classification_synthetic(n_samples=n, n_features=m,
                                       n_informative=64, class_sep=0.8)
    rows += run_dataset("synthetic", x, y, radius=1.0, epochs=epochs,
                        prefix="sae_factory")
    xl, yl, _ = lung_like(n_samples=n, n_features=m) if not full else lung_like()
    rows += run_dataset("lung_like", xl, yl, radius=1.0, epochs=epochs,
                        prefix="sae_factory")
    # no-rewind ablation: descent #2 fine-tunes the projected weights
    rows += run_dataset("synthetic_norewind", x, y, radius=1.0, epochs=epochs,
                        prefix="sae_factory", rewind=False,
                        only=("bilevel_l1inf",))
    return rows


def _factory_rows(full):
    from repro.training import sae_factory as F

    fcfg = F.SAEFactoryConfig(
        layers=(0,), harvest_steps=4 if full else 2,
        train_steps=60 if full else 12, sae_batch=64, microbatch=32,
        expansion=4 if full else 2, radius=0.5)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        out = F.run_factory(fcfg, d, seeds=(0, 1))
        dt = time.perf_counter() - t0
    rec = out["layers"][0]
    mmcs = rec["mmcs"]["seed0_vs_seed1"]
    mse = rec["metrics"][0]["mse"]
    rows = [("sae_factory_pipeline_layer0", dt * 1e6,
             f"mmcs={mmcs:.3f}_mse={mse:.4f}")]
    # head-structured variant (§6): 3-D encoder, tri-level l1,inf,inf ball
    hcfg = F.SAEFactoryConfig(
        layers=(0,), harvest_steps=4 if full else 2,
        train_steps=60 if full else 12, sae_batch=64, microbatch=32,
        expansion=4 if full else 2, radius=0.5, heads=2)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        out = F.run_factory(hcfg, d, seeds=(0, 1))
        dt = time.perf_counter() - t0
    rec = out["layers"][0]
    mmcs = rec["mmcs"]["seed0_vs_seed1"]
    mse = rec["metrics"][0]["mse"]
    rows.append(("sae_factory_pipeline_heads2_layer0", dt * 1e6,
                 f"mmcs={mmcs:.3f}_mse={mse:.4f}"
                 f"_levels={len(F.effective_levels(hcfg))}"))
    return rows


def _gsp_row(full):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    steps = 4 if full else 2
    res = subprocess.run(
        [sys.executable, "-c", _GSP_CHILD, str(steps)],
        capture_output=True, text=True, timeout=1200, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"gsp subprocess failed:\n{res.stderr[-3000:]}")
    payload = res.stdout.split("ROWS", 1)[1]
    return [(name, us, derived) for name, us, derived in json.loads(payload)]


def factory_sweep(full=False):
    return _tables_rows(full) + _factory_rows(full) + _gsp_row(full)
