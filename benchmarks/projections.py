"""Timing benchmarks for the paper's figures (1, 2, 3) and Table 1.

All candidates are jitted; we time steady-state (post-compile) medians on this
container's single CPU core. The paper's absolute numbers are C++/i9 — what
must reproduce is the *ordering and scaling*: bi-level ≥2.5× faster than the
exact (Chu-style semismooth Newton) projection, flat in the radius, linear in
nm; tri-level linear in m.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (available_methods, bilevel_l1inf, project_l1,
                        project_l1inf_exact, multilevel_project,
                        trilevel_l111, trilevel_l1infinf)


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # µs


def fig1_radius(rows=(), full=False):
    """Paper Fig 1: time vs radius, matrix 1000×10000 (scaled down unless full)."""
    n, m = (1000, 10000) if full else (500, 2000)
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.uniform(0, 1, (n, m)), jnp.float32)
    bl = jax.jit(lambda y, r: bilevel_l1inf(y, r))
    ex = jax.jit(lambda y, r: project_l1inf_exact(y, r))
    out = []
    for radius in (0.25, 0.5, 1.0, 2.0, 4.0):
        r = jnp.float32(radius)
        t_bl = _time(bl, y, r)
        t_ex = _time(ex, y, r)
        out.append((f"fig1_bilevel_l1inf_eta{radius}", t_bl,
                    f"speedup_vs_exact={t_ex / t_bl:.2f}"))
        out.append((f"fig1_exact_chu_eta{radius}", t_ex, f"n={n},m={m}"))
    return out


def fig2_size(full=False):
    """Paper Fig 2: time vs matrix size (m=1000, η=1 fixed)."""
    ns = (1000, 2000, 5000, 10000) if full else (250, 500, 1000, 2000)
    m = 1000 if full else 500
    rng = np.random.default_rng(1)
    bl = jax.jit(lambda y: bilevel_l1inf(y, 1.0))
    ex = jax.jit(lambda y: project_l1inf_exact(y, 1.0))
    out = []
    for n in ns:
        y = jnp.asarray(rng.uniform(0, 1, (n, m)), jnp.float32)
        t_bl = _time(bl, y)
        t_ex = _time(ex, y)
        out.append((f"fig2_bilevel_n{n}", t_bl,
                    f"speedup_vs_exact={t_ex / t_bl:.2f}"))
        out.append((f"fig2_exact_n{n}", t_ex, f"m={m}"))
    return out


def fig3_trilevel(full=False):
    """Paper Fig 3: tri-level time vs m (d=32, n=1000 fixed)."""
    d, n = (32, 1000) if full else (8, 250)
    ms = (250, 500, 1000, 2000) if full else (64, 128, 256, 512)
    rng = np.random.default_rng(2)
    t_inf = jax.jit(lambda y: trilevel_l1infinf(y, 1.0))
    t_111 = jax.jit(lambda y: trilevel_l111(y, 1.0))
    out = []
    for m in ms:
        y = jnp.asarray(rng.uniform(0, 1, (d, n, m)), jnp.float32)
        out.append((f"fig3_tri_l1infinf_m{m}", _time(t_inf, y, reps=3), f"d={d},n={n}"))
        out.append((f"fig3_tri_l111_m{m}", _time(t_111, y, reps=3), f"d={d},n={n}"))
    return out


def methods_sweep(full=False):
    """ℓ1 backend shoot-out: sort vs bisect vs filter over the fig2 size sweep.

    Two workload shapes per (n, m):

    * ``flat``  — one vector of n·m entries (the outer-step / Prop 6.3 shape);
      the largest default size already has n·m = 1e6, where the linear-time
      filter backend must beat sort by >= 1.5x on CPU (CI asserts the artifact).
    * ``batch`` — m vectors of length n with per-vector radii (the q = 1 inner
      step of the bi-/multi-level projections).
    """
    ns = (1000, 2000, 5000, 10000) if full else (250, 500, 1000, 2000)
    m = 1000 if full else 500
    rng = np.random.default_rng(4)
    methods = available_methods()
    out = []
    for n in ns:
        flat = jnp.asarray(rng.uniform(0, 1, (n * m,)), jnp.float32)
        batch = jnp.asarray(rng.uniform(0, 1, (m, n)), jnp.float32)
        radii = jnp.full((m,), 1.0, jnp.float32)
        for kind, y, r in (("flat", flat, 1.0), ("batch", batch, radii)):
            times = {}
            for method in methods:
                fn = jax.jit(lambda v, method=method, r=r:
                             project_l1(v, r, method=method))
                times[method] = _time(fn, y, reps=3)
            for method in methods:
                out.append((
                    f"methods_{kind}_{method}_n{n}", times[method],
                    f"nm={n * m},speedup_vs_sort={times['sort'] / times[method]:.2f}",
                ))
    return out


def plan_sweep(full=False):
    """Planner autotune sweep: auto vs every fixed backend, cold vs warm cache.

    Per workload (bi-level matrix, tri-level tensor, flat vector):

    * ``plan_cold_*``    — wall time of the FIRST ``make_plan`` + call with
      ``method="auto"`` (includes micro-benchmarking every candidate and
      jitting the winner) — the one-time cost a served workload amortizes.
    * ``plan_auto_*``    — steady-state of the autotuned plan. The acceptance
      bar: ``auto_vs_best`` ≤ 1.05 (auto is never >5% slower than the best
      fixed backend — it shares the winner's cached executable, so any gap is
      timer noise).
    * ``plan_fixed_*``   — steady-state of each fixed-method plan.
    * ``plan_warm_*``    — wall time of a repeat ``make_plan`` (cache hit:
      no autotune, no re-trace; microseconds).
    """
    from repro.core import plan as planmod

    n, m = (1000, 4000) if full else (400, 1000)
    d = 8
    workloads = [
        ("bilevel_l1inf", (n, m), [("inf", 1), ("1", 1)]),
        ("trilevel_l1infinf", (d, n // 4, m), [("inf", 1), ("inf", 1), ("1", 1)]),
        ("flat_l1", (n * m,), [("1", 1)]),
    ]
    rng = np.random.default_rng(5)
    out = []
    for wname, shape, levels in workloads:
        y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
        planmod.clear_cache()
        t0 = time.perf_counter()
        p = planmod.make_plan(shape, jnp.float32, levels)
        jax.block_until_ready(p(y, 1.0))
        cold = (time.perf_counter() - t0) * 1e6
        # Time each *backend executable* once, interleaved min-of-rounds.
        # Plans with the same resolved ``.method`` share one cached jitted
        # executable (that is the planner's cache contract), so they must get
        # the same number — timing the auto plan and the same-method fixed
        # plan in separate blocks folds scheduler noise and machine drift
        # into the auto_vs_best ratio instead of backend choice.
        for attempt in range(2):
            plans = {"auto": p}
            for meth in available_methods():
                plans[meth] = planmod.make_plan(shape, jnp.float32, levels,
                                                method=meth)
            backends = {fp.method: fp for fp in plans.values()}
            for fp in backends.values():
                for _ in range(2):
                    jax.block_until_ready(fp(y, 1.0))
            bt = dict.fromkeys(backends, float("inf"))
            for _ in range(25):
                for bname, fp in backends.items():
                    t0 = time.perf_counter()
                    jax.block_until_ready(fp(y, 1.0))
                    bt[bname] = min(bt[bname],
                                    (time.perf_counter() - t0) * 1e6)
            times = {name: bt[fp.method] for name, fp in plans.items()}
            t_auto = times.pop("auto")
            best_name = min(times, key=times.get)
            best = times[best_name]
            if t_auto <= 1.05 * best or attempt:
                break
            # the autotune verdict is process-permanent and was taken in the
            # (noisy) cold window; one bounded re-tune before reporting, so a
            # shared CI runner's load spike cannot fail the gate alone
            planmod.clear_cache()
            p = planmod.make_plan(shape, jnp.float32, levels)
        # cold row emitted AFTER the attempt loop so its winner always agrees
        # with the plan_auto_* row (a re-tune may change it)
        out.append((f"plan_cold_{wname}", cold,
                    f"winner={p.method},candidates={len(p.timings_us)}"))
        out.append((f"plan_auto_{wname}", t_auto,
                    f"winner={p.method},best_fixed={best_name},"
                    f"auto_vs_best={t_auto / best:.3f}"))
        for meth, t in times.items():
            out.append((f"plan_fixed_{meth}_{wname}", t,
                        f"vs_auto={t / t_auto:.2f}"))
        t0 = time.perf_counter()
        planmod.make_plan(shape, jnp.float32, levels)
        warm = (time.perf_counter() - t0) * 1e6
        out.append((f"plan_warm_{wname}", warm, "plan_cache=hit"))
    return out


_SHARDED_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (multilevel_project, multilevel_project_sharded,
                        sharded_collective_bytes)

FULL = json.loads(sys.argv[1])
def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6

mesh = jax.make_mesh((8,), ("model",))
n, m = (1000, 10000) if FULL else (256, 2048)
d = 32 if FULL else 8
designs = [
    ("bilevel_l1inf",    (n, m),      [("inf",1),("1",1)],          P(None, "model")),
    ("trilevel_l1infinf",(d, n//4, m),[("inf",1),("inf",1),("1",1)],P(None, None, "model")),
    ("bilevel_l12_axis0",(m, n),      [("2",1),("1",1)],            P("model", None)),
]
rows = []
rng = np.random.default_rng(7)
for name, shape, levels, spec in designs:
    y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
    ys = jax.device_put(y, NamedSharding(mesh, spec))
    sched_fn = jax.jit(lambda v, r, levels=levels, spec=spec:
                       multilevel_project_sharded(v, levels, r, mesh=mesh,
                                                  spec=spec, method="sort"))
    gather_fn = jax.jit(lambda v, r, levels=levels:
                        multilevel_project(v, levels, r, method="sort"),
                        out_shardings=NamedSharding(mesh, spec))
    r = jnp.float32(2.0)
    diff = float(jnp.abs(sched_fn(ys, r) - gather_fn(ys, r)).max())
    assert diff < 1e-4, (name, diff)
    t_sched = _time(sched_fn, ys, r)
    t_gather = _time(gather_fn, ys, r)
    cb = sharded_collective_bytes(shape, levels, spec, mesh)
    rows.append([f"sharded_schedule_{name}", t_sched,
                 f"coll_bytes={cb['schedule_bytes']},"
                 f"bytes_ratio={cb['ratio']:.0f}x,"
                 f"speedup_vs_gather={t_gather / t_sched:.2f}"])
    rows.append([f"sharded_gather_{name}", t_gather,
                 f"coll_bytes={cb['gather_bytes']},shape={shape}"])
    per = ";".join(f"{s['step']}:{s['bytes']}" for s in cb["per_step"])
    rows.append([f"sharded_bytes_{name}", float(cb["schedule_bytes"]), per])
print("ROWS" + json.dumps(rows))
"""


def sharded_sweep(full=False):
    """``--only sharded``: the generalized DESIGN.md §3 argument, measured.

    Runs in a subprocess with a forced 8-device host mesh (the parent process
    must keep its single device). Per norm design: steady-state wall-clock of
    the schedule executor vs. jitted gather-and-project (GSPMD) on the same
    committed sharded input, plus the analytic per-level collective payload
    of both — the ``bytes_ratio`` is the aggregated-extent factor of
    Proposition 6.4. The ``sharded_bytes_*`` rows carry the per-step payload
    breakdown in ``derived``.
    """
    import json as _json
    import os
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    res = subprocess.run(
        [_sys.executable, "-c", _SHARDED_CHILD, _json.dumps(bool(full))],
        capture_output=True, text=True, timeout=1200, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"sharded sweep failed:\n{res.stderr[-3000:]}")
    payload = res.stdout.split("ROWS", 1)[1]
    return [(name, us, derived) for name, us, derived in _json.loads(payload)]


_SHARDED_CODEGEN_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import multilevel_project_sharded, plan as planmod

FULL = json.loads(sys.argv[1])
mesh = jax.make_mesh((8,), ("model",))
n, m = (1000, 10000) if FULL else (128, 1024)
d = 8
designs = [
    ("bilevel_l1inf",     (n, m),       [("inf",1),("1",1)],
     P(None, "model")),
    ("trilevel_l1infinf", (d, n//8, m), [("inf",1),("inf",1),("1",1)],
     P(None, None, "model")),
    ("bilevel_l11_fin",   (n, m//2),    [("1",1),("1",1)],
     P("model", None)),
]
rows = []
rng = np.random.default_rng(13)
for name, shape, levels, spec in designs:
    y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
    ys = jax.device_put(y, NamedSharding(mesh, spec))
    r = jnp.float32(2.0)
    fns = {
        "fused": jax.jit(lambda v, rr, levels=levels, spec=spec:
                         multilevel_project_sharded(
                             v, levels, rr, mesh=mesh, spec=spec,
                             backend="codegen", interpret=True)),
        "jnp": jax.jit(lambda v, rr, levels=levels, spec=spec:
                       multilevel_project_sharded(v, levels, rr, mesh=mesh,
                                                  spec=spec)),
    }
    diff = float(jnp.abs(fns["fused"](ys, r) - fns["jnp"](ys, r)).max())
    assert diff < 1e-5, (name, diff)
    for fn in fns.values():
        for _ in range(2):
            jax.block_until_ready(fn(ys, r))
    best = dict.fromkeys(fns, float("inf"))
    for _ in range(10):
        for key, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(ys, r))
            best[key] = min(best[key], (time.perf_counter() - t0) * 1e6)
    rows.append([f"sharded_codegen_fused_{name}", best["fused"],
                 f"vs_jnp={best['fused'] / best['jnp']:.3f},interpret=True"])
    rows.append([f"sharded_codegen_jnpbody_{name}", best["jnp"],
                 f"shape={shape}"])

# method="auto" on the sharded key: the fused backend competes, and the auto
# plan must sit within 5% of the best fixed backend (bounded re-tune, like
# plan_sweep: the verdict is process-permanent and the cold window is noisy)
name, shape, levels, spec = designs[0]
y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
ys = jax.device_put(y, NamedSharding(mesh, spec))
sharding = ys.sharding
for attempt in range(2):
    planmod.clear_cache()
    p = planmod.make_plan(shape, jnp.float32, levels, sharding=sharding,
                          interpret=True)
    fixed = {}
    for meth in ("sharded", "sharded_codegen", "sort", "bisect"):
        fixed[meth] = planmod.make_plan(shape, jnp.float32, levels,
                                        sharding=sharding, interpret=True,
                                        method=meth)
    cands = dict(fixed, auto=p)
    execs = {fp.method: fp for fp in cands.values()}
    for fp in execs.values():
        for _ in range(2):
            jax.block_until_ready(fp(ys, 2.0))
    bt = dict.fromkeys(execs, float("inf"))
    for _ in range(15):
        for bname, fp in execs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fp(ys, 2.0))
            bt[bname] = min(bt[bname], (time.perf_counter() - t0) * 1e6)
    t_auto = bt[p.method]
    best_name = min(fixed, key=lambda k: bt[fixed[k].method])
    t_best = bt[fixed[best_name].method]
    if t_auto <= 1.05 * t_best or attempt:
        break
rows.append([f"sharded_codegen_plan_auto_{name}", t_auto,
             f"winner={p.method},best_fixed={best_name},"
             f"auto_vs_best={t_auto / t_best:.3f}"])
print("ROWS" + json.dumps(rows))
"""


def sharded_codegen_sweep(full=False):
    """``--only sharded_codegen``: the fused shard-local stages, measured.

    Subprocess with a forced 8-device CPU mesh (interpret-mode kernels, like
    ``codegen_sweep`` off-TPU — absolute µs are meaningless, the artifact
    asserts structural ratios that CI gates against the committed copy):

    * ``sharded_codegen_fused_*`` — the ``backend="codegen"`` schedule body
      vs the reference jnp body on the same committed sharded input; the
      ``vs_jnp`` ratio is the fusion overhead/gain and must stay within
      1.25x of the committed artifact's ratio.
    * ``sharded_codegen_plan_auto_*`` — ``method="auto"`` on the sharded key
      with the fused backend competing: auto within 5% of the best fixed
      backend (bounded re-tune, plan_sweep protocol).
    * ``sharded_codegen_blocktune_*`` (parent process, single device) — the
      measured block-size autotuner: the tuned plan within 5% of the best
      fixed block of the candidate grid.
    """
    import json as _json
    import os
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    res = subprocess.run(
        [_sys.executable, "-c", _SHARDED_CODEGEN_CHILD,
         _json.dumps(bool(full))],
        capture_output=True, text=True, timeout=1800, env=env)
    if res.returncode != 0:
        raise RuntimeError(
            f"sharded_codegen sweep failed:\n{res.stderr[-3000:]}")
    payload = res.stdout.split("ROWS", 1)[1]
    rows = [(name, us, derived) for name, us, derived in _json.loads(payload)]
    return rows + blocktune_rows(full)


def blocktune_rows(full=False):
    """Measured block-size autotuner rows (single device, interpret mode)."""
    from repro.core.schedule import compile_schedule
    from repro.kernels import codegen
    from repro.kernels.codegen.tiling import candidate_tile_plans

    n, m = (1000, 10000) if full else (256, 2048)
    workloads = [
        ("bilevel_l1inf", (n, m), [("inf", 1), ("1", 1)]),
        ("trilevel_l1infinf", (8, n // 8, m),
         [("inf", 1), ("inf", 1), ("1", 1)]),
    ]
    rng = np.random.default_rng(17)
    out = []
    for name, shape, levels in workloads:
        y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
        r = jnp.float32(2.0)
        sched = compile_schedule(shape, levels)
        cands = candidate_tile_plans(sched, jnp.float32)
        fns = {tp: jax.jit(codegen.build(shape, levels, jnp.float32,
                                         interpret=True, tile_plan=tp))
               for tp in cands}
        for fn in fns.values():
            for _ in range(2):
                jax.block_until_ready(fn(y, r))
        for attempt in range(2):
            best = dict.fromkeys(fns, float("inf"))
            for _ in range(8):
                for tp, fn in fns.items():
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(y, r))
                    best[tp] = min(best[tp], (time.perf_counter() - t0) * 1e6)
            codegen.clear_tile_cache()
            tuned = codegen.autotune_tiles(shape, levels, jnp.float32,
                                           interpret=True, measure=True)
            t_tuned, t_best = best[tuned], min(best.values())
            if t_tuned <= 1.05 * t_best or attempt:
                break
        out.append((
            f"sharded_codegen_blocktune_{name}", t_tuned,
            f"tuned_vs_best={t_tuned / t_best:.3f},"
            f"n_candidates={len(cands)},"
            f"block={tuned.block_n}x{tuned.block_m}"))
    return out


def codegen_sweep(full=False):
    """``--only codegen``: generated fused kernels vs the hand-written golden
    kernels vs the jnp schedule path, on the golden kernels' home workloads.

    Off-TPU both kernel paths run in Pallas interpret mode, so the absolute
    µs are meaningless there — what the artifact asserts is the *structural
    parity* ``vs_hand`` ratio (generated and golden kernels lower to the same
    reduce → θ-solve → apply pipeline, so the generated one must sit within
    10% of the hand-written on its home design). On TPU the same rows measure
    real kernels and ``vs_jnp`` becomes the fusion speedup. Candidates are
    timed interleaved min-of-rounds (the autotuner's protocol) so machine
    drift lands on all three equally instead of inside the ratio.
    """
    import functools

    from repro.kernels import codegen
    from repro.kernels.bilevel_l1inf import bilevel_l1inf_pallas
    from repro.kernels.trilevel_l1infinf import trilevel_l1infinf_pallas

    interpret = jax.devices()[0].platform != "tpu"
    n, m = (1000, 10000) if full else (256, 1024)
    d = 8
    workloads = [
        ("bilevel_l1inf", (n, m), [("inf", 1), ("1", 1)],
         bilevel_l1inf_pallas),
        ("trilevel_l1infinf", (d, n // 4, m),
         [("inf", 1), ("inf", 1), ("1", 1)], trilevel_l1infinf_pallas),
    ]
    rng = np.random.default_rng(9)
    out = []
    for name, shape, levels, hand in workloads:
        y = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
        r = jnp.float32(2.0)
        fns = {
            "generated": jax.jit(codegen.build(
                shape, levels, jnp.float32, method="bisect",
                interpret=interpret)),
            "hand": jax.jit(functools.partial(hand, method="bisect",
                                              interpret=interpret)),
            "jnp": jax.jit(lambda v, rr, levels=levels: multilevel_project(
                v, levels, rr, method="bisect")),
        }
        diff = float(jnp.abs(fns["generated"](y, r) - fns["hand"](y, r)).max())
        assert diff < 1e-5, (name, diff)
        for fn in fns.values():
            for _ in range(2):
                jax.block_until_ready(fn(y, r))
        best = dict.fromkeys(fns, float("inf"))
        for _ in range(20):
            for key, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(y, r))
                best[key] = min(best[key], (time.perf_counter() - t0) * 1e6)
        out.append((f"codegen_generated_{name}", best["generated"],
                    f"vs_hand={best['generated'] / best['hand']:.3f},"
                    f"vs_jnp={best['generated'] / best['jnp']:.2f},"
                    f"interpret={interpret}"))
        out.append((f"codegen_hand_{name}", best["hand"], f"shape={shape}"))
        out.append((f"codegen_jnp_{name}", best["jnp"], f"shape={shape}"))
    return out


def table1_scaling(full=False):
    """Empirical complexity fit (Table 1): log-log slope of time vs nm."""
    sizes = ((200, 200), (400, 400), (800, 800), (1600, 1600)) if not full \
        else ((500, 500), (1000, 1000), (2000, 2000), (4000, 4000))
    rng = np.random.default_rng(3)
    bl = jax.jit(lambda y: bilevel_l1inf(y, 1.0))
    ex = jax.jit(lambda y: project_l1inf_exact(y, 1.0))
    t_bl, t_ex, nm = [], [], []
    for n, m in sizes:
        y = jnp.asarray(rng.uniform(0, 1, (n, m)), jnp.float32)
        t_bl.append(_time(bl, y, reps=3))
        t_ex.append(_time(ex, y, reps=3))
        nm.append(n * m)
    s_bl = np.polyfit(np.log(nm), np.log(t_bl), 1)[0]
    s_ex = np.polyfit(np.log(nm), np.log(t_ex), 1)[0]
    return [
        ("table1_bilevel_scaling_exponent", t_bl[-1],
         f"loglog_slope={s_bl:.2f}_theory=1.0"),
        ("table1_exact_scaling_exponent", t_ex[-1],
         f"loglog_slope={s_ex:.2f}_theory>=1.0"),
    ]


def fig4_parallel():
    """Paper Fig 4 analogue — the parallel decomposition on a mesh.

    No multi-core wall-clock exists in this container; we report the paper's
    own complexity model (work/depth from Prop 6.4) and the collective-bytes
    ratio of the sharded bi-level projection vs a gathered exact projection
    (the factor-n traffic reduction of DESIGN.md §3).
    """
    from repro.core.multilevel import work_depth
    out = []
    n, m = 1000, 10000
    work, depth = work_depth((n, m), [(jnp.inf, 1), (1, 1)])
    for workers in (1, 2, 4, 8, 12, 64, 256):
        t_par = work / workers + depth
        out.append((f"fig4_modelled_gain_w{workers}", t_par,
                    f"gain={work / t_par:.1f}x_ideal={workers}"))
    # collective traffic: sharded bi-level moves m floats; gathered exact n*m
    out.append(("fig4_coll_bytes_bilevel_sharded", m * 4, "all_gather_of_colnorms"))
    out.append(("fig4_coll_bytes_exact_gathered", n * m * 4,
                f"ratio={n}x_prop6.4"))
    return out
