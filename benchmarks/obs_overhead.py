"""Observability overhead benchmark: what the telemetry costs, measured.

The ``obs`` section prices the PR-10 observability layer on its two hot
paths and pins the price in ``BENCH_obs_overhead.json``:

* **serving** — one ``ProjectionEngine`` request (submit → inline drain →
  claim), ``instrument=True`` vs ``instrument=False``. The instrumented
  engine performs a handful of registry operations per request (queue-depth
  gauge, queue/e2e/dispatch histograms, event counters); the bare engine
  performs none. Gate: ``overhead_on`` ≤ 1.10.
* **training** — a cadence window of projected train steps (``_CADENCE``
  consecutive steps — what one telemetry period costs per step,
  steady-state), four builds of the SAME workload:

  - ``bare``            — ``telemetry_every=0`` (no telemetry code at all);
  - ``compiled_out``    — telemetry requested but traced with the bridge
    DISABLED. ``obs.jax_bridge``'s gate is trace-time static, so this
    lowers to a bit-identical program — the measured overhead is pure
    noise. Gate: ``overhead_off`` ≤ 1.02;
  - ``on``              — ``telemetry_every=_CADENCE`` traced with the
    bridge ENABLED: loss/grad-norm/sparsity/feasibility callbacks fire
    once per window inside the cadence ``lax.cond``. Gate:
    ``overhead_on`` ≤ 1.10;
  - ``marks``           — ``telemetry_marks=True`` on top: the ordered
    epilogue mark pair serializes a host round-trip into EVERY step.
    Priced, NOT gated — marks are the documented opt-in deep-dive tool
    (``host callbacks on CPU cost O(100µs) each; ordering forbids riding
    the cadence cond``), not part of the default telemetry configuration.

Timing is interleaved min-of-rounds (the repo's standard estimator:
container CPU contention only ever inflates a round, so the min is stable,
and interleaving decorrelates slow spells across the compared sides); each
round ends with ``jax.effects_barrier()`` so one side's in-flight
callbacks never bleed into the next side's measurement.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.types import ProjectionSpec, TrainConfig
from repro.obs import jax_bridge
from repro.training import make_train_step

BILEVEL = (("inf", 1), ("1", 1))

_ROUNDS = 9
_CADENCE = 10   # the telemetry period the "on" rows amortize over


def _interleaved_min(named_fns, rounds=_ROUNDS, warmup=2):
    """min-of-rounds µs per side, sides interleaved within every round."""
    for _, fn in named_fns:
        for _ in range(warmup):
            fn()
        jax.effects_barrier()
    best = {name: float("inf") for name, _ in named_fns}
    for _ in range(rounds):
        for name, fn in named_fns:
            t0 = time.perf_counter()
            fn()
            jax.effects_barrier()
            best[name] = min(best[name], (time.perf_counter() - t0) * 1e6)
    return best


# ----------------------------------------------------------------- serving

def _engine_round(eng, payloads, levels):
    tks = [eng.submit(y, levels, radius=1.0) for y in payloads]
    eng.drain()
    for tk in tks:
        jax.block_until_ready(eng.result(tk))


def engine_overhead(shape=(32, 64), k=8):
    """Per-request µs, instrumented vs bare engine, same plans/payloads."""
    from repro.serving import ProjectionEngine

    rng = np.random.default_rng(3)
    payload = lambda: jnp.asarray(rng.normal(size=shape), jnp.float32)
    levels = list(BILEVEL)
    engines = {
        "bare": ProjectionEngine(method="sort", instrument=False,
                                 start=False),
        "instrumented": ProjectionEngine(method="sort", start=False),
    }
    try:
        for eng in engines.values():
            eng.prewarm(shape, jnp.float32, levels)
            eng.wait_warm(timeout=300.0)
        best = _interleaved_min([
            (name, lambda e=eng: _engine_round(
                e, [payload() for _ in range(k)], levels))
            for name, eng in engines.items()])
    finally:
        for eng in engines.values():
            eng.stop()
    return best["bare"] / k, best["instrumented"] / k


# ---------------------------------------------------------------- training

def _train_setup():
    """A projected training workload (fused epilogue path), sized so one
    bare step takes tens of ms on the container — the scale where the
    telemetry's fixed per-step cost (effectful jits dispatch through the
    slow Python path: ~2 ms/call on CPU) is priced against a step that is
    at least the size of any real training step, not a toy."""
    rng = np.random.default_rng(11)
    shapes = {"w_up": (16, 256, 512), "w_gate": (1024, 512),
              "w_skip": (256, 64)}
    params = {name: jnp.asarray(rng.normal(size=s) * 0.5, jnp.float32)
              for name, s in shapes.items()}
    spec = ProjectionSpec(pattern=r"w_up|w_gate", levels=list(BILEVEL),
                          radius=1.0, method="bisect")
    tcfg = TrainConfig(lr=1e-3, warmup=1, total_steps=100, microbatch=4,
                       master_dtype="", projection=spec)

    def loss_fn(p, x):
        acts = sum(jnp.sum(w.astype(jnp.float32) ** 2) for w in
                   jax.tree_util.tree_leaves(p))
        return acts * jnp.mean(x.astype(jnp.float32) ** 2)

    from repro.optim import adamw

    state = {"params": params, "opt": adamw.init(params, tcfg)}
    batch = {"tokens": jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)}
    return tcfg, loss_fn, state, batch


def train_overhead():
    """Per-step µs over one telemetry period, the four builds."""
    tcfg, loss_fn, state, batch = _train_setup()

    def build(telemetry_every, bridge_on, marks=False):
        with jax_bridge.enabled_scope(bridge_on):
            fn = jax.jit(make_train_step(
                None, tcfg, None, telemetry_every=telemetry_every,
                telemetry_marks=marks, loss_fn=loss_fn))
            jax.block_until_ready(fn(state, batch))   # trace under the gate
        return fn

    steps = {
        "bare": build(0, False),
        "compiled_out": build(_CADENCE, False, marks=True),
        "on": build(_CADENCE, True),
        "marks": build(_CADENCE, True, marks=True),
    }
    # the rigorous form of the overhead-off claim: a bridge-disabled trace
    # lowers to the very same program, so the measured ratio is pure noise
    with jax_bridge.enabled_scope(False):
        hlo_identical = (
            steps["bare"].lower(state, batch).as_text()
            == steps["compiled_out"].lower(state, batch).as_text())

    def window(fn):
        # one full telemetry period, threading the state so the step
        # counter advances through the cadence cond's firing step
        s = state
        for _ in range(_CADENCE):
            s, _m = fn(s, batch)
        jax.block_until_ready(s["opt"]["step"])

    # callbacks must run under an enabled bridge so the host side actually
    # records (measuring the full cost, not a dropped payload)
    with jax_bridge.enabled_scope(True):
        best = _interleaved_min(
            [(name, lambda f=fn: window(f)) for name, fn in steps.items()],
            warmup=1)
    out = {name: us / _CADENCE for name, us in best.items()}
    out["hlo_identical"] = hlo_identical
    return out


def obs_sweep(full=False):
    """The ``obs`` benchmark section (BENCH_obs_overhead.json)."""
    del full  # one scale: the gated quantities are ratios, machine cancels
    bare_rq, instr_rq = engine_overhead()
    t = train_overhead()
    r_engine = instr_rq / bare_rq
    r_off = t["compiled_out"] / t["bare"]
    r_on = t["on"] / t["bare"]
    r_marks = t["marks"] / t["bare"]
    return [
        ("obs_engine_request_bare", bare_rq, "instrument=False"),
        ("obs_engine_request_instrumented", instr_rq,
         f"bare_us={bare_rq:.1f},overhead_on={r_engine:.3f}"),
        ("obs_train_step_bare", t["bare"], "telemetry_every=0"),
        ("obs_train_step_telemetry_compiled_out", t["compiled_out"],
         f"bare_us={t['bare']:.1f},overhead_off={r_off:.3f},"
         f"hlo_identical={'yes' if t['hlo_identical'] else 'no'}"),
        ("obs_train_step_telemetry_on", t["on"],
         f"bare_us={t['bare']:.1f},cadence={_CADENCE},"
         f"overhead_on={r_on:.3f}"),
        ("obs_train_step_telemetry_marks", t["marks"],
         f"bare_us={t['bare']:.1f},marks_overhead={r_marks:.3f},"
         f"gated=no"),
    ]
