"""Projected-optimizer epilogue benchmark: fused single-pass vs unfused.

The ``train`` section times exactly the two code paths a projected train step
can take after the gradient is ready:

* **unfused** — the pre-fusion three-dispatch sequence a standalone optimizer
  stack executes: ``jit(adamw.update)`` writes p′, ``jit(projection hook)``
  reads p′ back and writes Π(p′), and (when a master copy exists) a third
  jitted sweep re-syncs it — three round-trips through HBM per matched leaf;
* **fused** — one ``jit(fused_update)`` dispatch (``optim/fused_step.py``):
  update → project (f32) → cast, each leaf read once / written once.

Reported per workload: fused µs/step, unfused µs/step, their ratio
(``fused_vs_unfused``, the gated quantity — the committed artifact
``benchmarks/results/BENCH_train_step.json`` pins it and CI's training job
re-measures; machine speed cancels in the ratio), and the HBM sweep counts
(``hbm_passes=1v3``) the fusion removes.  Timing is interleaved min-of-rounds
(same estimator as the planner autotuner: contention only inflates a round).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.types import ProjectionSpec, TrainConfig
from repro.optim import adamw, fused_step
from repro.optim.projection_hook import make_projection_hook

BILEVEL = (("inf", 1), ("1", 1))
TRILEVEL = (("inf", 1), ("inf", 1), ("1", 1))

_ROUNDS = 7   # interleaved rounds; min per side kept


def _params(shapes, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return {name: jnp.asarray(rng.normal(size=s) * 0.5, dtype)
            for name, s in shapes.items()}


def _workloads(full):
    # (tag, shapes, levels, TrainConfig overrides); w_up/w_in match the spec
    # pattern, w_skip rides along unmatched (the fusion must not tax it).
    # All three use the mixed-precision layout projected LLM training runs
    # (low-precision params + f32 master), so the unfused baseline honestly
    # pays its third master-sync sweep.
    k = 4 if full else 1
    mixed = dict(param_dtype="bfloat16", master_dtype="float32")
    return [
        ("bilevel_bf16",
         {"w_up": (4 * k, 64 * k, 256), "w_in": (128 * k, 256),
          "w_skip": (128 * k, 64)},
         BILEVEL, dict(mixed)),
        ("trilevel_bf16",
         {"w_up": (2 * k, 8, 32 * k, 128), "w_in": (8, 64 * k, 128),
          "w_skip": (128 * k, 64)},
         TRILEVEL, dict(mixed)),
        ("int8_master",
         {"w_up": (4 * k, 64 * k, 256), "w_in": (128 * k, 256),
          "w_skip": (128 * k, 64)},
         BILEVEL, dict(mixed, moment_dtype="int8")),
    ]


def _unfused_pipeline(cfg):
    """The pre-fusion sequence as three separate jitted dispatches."""
    hook = make_projection_hook(cfg.projection)
    up = jax.jit(lambda g, s, p: adamw.update(g, s, p, cfg))
    proj = jax.jit(hook)
    sync = jax.jit(lambda p, m: jax.tree_util.tree_map(
        lambda w, mm: w.astype(mm.dtype), p, m))

    def step(g, s, p):
        new_p, new_s, metrics = up(g, s, p)
        new_p = proj(new_p, new_s["step"])
        if "master" in new_s:
            new_s = dict(new_s)
            new_s["master"] = sync(new_p, new_s["master"])
        return new_p, new_s, metrics

    return step


def _min_of_rounds(fused_fn, unfused_fn, args, rounds=_ROUNDS):
    for fn in (fused_fn, unfused_fn):       # compile + warm both sides
        for _ in range(2):
            jax.block_until_ready(fn(*args))
    best = {"fused": float("inf"), "unfused": float("inf")}
    for _ in range(rounds):
        for name, fn in (("fused", fused_fn), ("unfused", unfused_fn)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], (time.perf_counter() - t0) * 1e6)
    return best["fused"], best["unfused"]


def train_sweep(full=False):
    """The ``train`` benchmark section (BENCH_train_step.json)."""
    rng = np.random.default_rng(1)
    rows = []
    for tag, shapes, levels, over in _workloads(full):
        spec = ProjectionSpec(pattern=r"w_up|w_in", levels=levels,
                              radius=1.0, method="bisect")
        cfg = TrainConfig(lr=1e-3, warmup=1, total_steps=100,
                          projection=spec, **over)
        params = _params(shapes, jnp.dtype(cfg.param_dtype))
        grads = jax.tree_util.tree_map(
            lambda p: jnp.asarray(rng.normal(size=p.shape), p.dtype), params)
        state = adamw.init(params, cfg)

        fused = fused_step.make_fused_step(cfg, donate=False)
        unfused = _unfused_pipeline(cfg)
        t_fused, t_unfused = _min_of_rounds(fused, unfused,
                                            (grads, state, params))
        ratio = t_fused / t_unfused
        n_par = sum(int(np.prod(s)) for s in shapes.values())
        rows.append((
            f"train_step_fused_{tag}", t_fused,
            f"unfused_us={t_unfused:.1f},fused_vs_unfused={ratio:.3f},"
            f"hbm_passes=1v3,params={n_par}"))
    return rows
