"""Fused AdamW-update+project epilogue (optim/fused_step.py).

Parity: on the f32/no-master path the fused step is operation-for-operation
the unfused sequence (adamw.update → projection hook → master sync), so the
two must agree to float tolerance.  On the cast paths (bf16 params, int8
moments, master dtype) exact parity is not the contract — feasibility is:
``multilevel_norm(W, ν) <= η·(1 + O(eps))`` after EVERY fused train step
(ISSUE 7 satellite: the paper's constraint survives the fused epilogue on
all dtype paths).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.types import ProjectionSpec, TrainConfig
from repro.core import multilevel
from repro.optim import adamw, fused_step
from repro.optim.projection_hook import make_projection_hook

BILEVEL = (("inf", 1), ("1", 1))
TRILEVEL = (("inf", 1), ("inf", 1), ("1", 1))
PATTERN = r"w_up|w_in"


def _tree(seed=0, dtype=jnp.float32, scale=0.5):
    rng = np.random.default_rng(seed)

    def mk(*s):
        return jnp.asarray(rng.normal(size=s) * scale, dtype)

    return {
        "blocks": {"mlp": {"w_up": mk(3, 16, 64), "w_down": mk(3, 64, 16)},
                   "attn": {"w_in": mk(16, 64)}},
        "emb": mk(50, 16),
    }


def _unfused(grads, state, params, cfg):
    """The pre-fusion three-pass sequence from training/step.py."""
    hook = make_projection_hook(cfg.projection)
    new_params, new_opt, metrics = adamw.update(grads, state, params, cfg)
    new_params = hook(new_params, new_opt["step"])
    if "master" in new_opt and cfg.projection is not None \
            and cfg.projection.enabled:
        new_opt = dict(new_opt)
        new_opt["master"] = jax.tree_util.tree_map(
            lambda p, m: p.astype(m.dtype), new_params, new_opt["master"])
    return new_params, new_opt, metrics


def _feasibility(w, levels):
    """max over leading (stacked) axes of the composed ν-norm."""
    need = sum(k for _, k in levels)

    def f(x):
        return multilevel.multilevel_norm(x.astype(jnp.float32), list(levels))

    for _ in range(w.ndim - need):
        f = jax.vmap(f)
    return float(jnp.max(jnp.atleast_1d(f(w))))


def _assert_trees_close(a, b, atol):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(x, y, atol=atol), a, b)


class TestFusedParity:
    def test_matches_unfused_f32(self):
        spec = ProjectionSpec(pattern=PATTERN, levels=BILEVEL, radius=1.5,
                              method="bisect")
        cfg = TrainConfig(lr=0.05, warmup=1, total_steps=20, master_dtype="",
                          projection=spec)
        params = _tree(0)
        sa = sb = adamw.init(params, cfg)
        pa = pb = params
        for i in range(3):
            g = _tree(10 + i, scale=1.0)
            pa, sa, ma = fused_step.fused_update(g, sa, pa, cfg)
            pb, sb, mb = _unfused(g, sb, pb, cfg)
            _assert_trees_close(pa, pb, 1e-6)
            _assert_trees_close(sa["m"], sb["m"], 1e-6)
            _assert_trees_close(sa["v"], sb["v"], 1e-6)
            np.testing.assert_allclose(ma["grad_norm"], mb["grad_norm"],
                                       rtol=1e-6)

    def test_no_projection_is_plain_adamw(self):
        cfg = TrainConfig(lr=0.01, warmup=1, total_steps=20, master_dtype="")
        params = _tree(1)
        g = _tree(2, scale=1.0)
        opt = adamw.init(params, cfg)
        pa, sa, _ = fused_step.fused_update(g, opt, params, cfg)
        pb, sb, _ = adamw.update(g, opt, params, cfg)
        _assert_trees_close(pa, pb, 1e-7)
        _assert_trees_close(sa, sb, 1e-7)

    def test_every_gate(self):
        spec = ProjectionSpec(pattern=PATTERN, levels=BILEVEL, radius=0.5,
                              method="bisect", every=2)
        cfg = TrainConfig(lr=0.0, weight_decay=0.0, warmup=1, total_steps=20,
                          master_dtype="", projection=spec)
        params = _tree(3, scale=2.0)  # infeasible on purpose; lr=0 preserves
        opt = adamw.init(params, cfg)
        p1, s1, _ = fused_step.fused_update(_tree(4), opt, params, cfg)
        # step 1: off-cycle -> NOT projected (still infeasible)
        assert _feasibility(p1["blocks"]["mlp"]["w_up"], BILEVEL) > 0.5 * 1.01
        p2, _, _ = fused_step.fused_update(_tree(5), s1, p1, cfg)
        # step 2: projected -> feasible
        assert _feasibility(p2["blocks"]["mlp"]["w_up"], BILEVEL) <= 0.5 * 1.001

    def test_jitted_entry_with_donation(self):
        spec = ProjectionSpec(pattern=PATTERN, levels=BILEVEL, radius=1.0,
                              method="bisect")
        cfg = TrainConfig(lr=0.05, warmup=1, total_steps=20, master_dtype="",
                          projection=spec)
        params = _tree(6)
        opt = adamw.init(params, cfg)
        step = fused_step.make_fused_step(cfg, donate=True)
        p, s, m = step(_tree(7, scale=1.0), opt, params)
        assert int(s["step"]) == 1 and np.isfinite(float(m["grad_norm"]))
        assert _feasibility(p["blocks"]["attn"]["w_in"], BILEVEL) <= 1.0 * (
            1 + 1e-5)


class TestFusedFeasibilityProperty:
    """‖W‖_ν ≤ η(1 + O(eps)) after every fused step, across dtype paths."""

    PATHS = [
        ("f32",          BILEVEL,  "float32", "float32", ""),
        ("int8_moments", BILEVEL,  "float32", "int8",    ""),
        ("bf16_master",  BILEVEL,  "bfloat16", "float32", "float32"),
        ("trilevel",     TRILEVEL, "float32", "float32", ""),
        ("tri_int8_bf16", TRILEVEL, "bfloat16", "int8",   "float32"),
    ]

    @pytest.mark.parametrize("name,levels,pdt,mdt,master", PATHS)
    def test_feasible_after_every_step(self, name, levels, pdt, mdt, master):
        radius = 1.25
        spec = ProjectionSpec(pattern=PATTERN, levels=levels, radius=radius,
                              method="bisect")
        cfg = TrainConfig(lr=0.1, warmup=1, total_steps=20, param_dtype=pdt,
                          moment_dtype=mdt, master_dtype=master,
                          projection=spec)
        need = sum(k for _, k in levels)
        params = _tree(20, dtype=jnp.dtype(pdt), scale=1.0)
        opt = adamw.init(params, cfg)
        # dtype-eps term for the post-projection cast + a floor for the
        # bisection θ-solver's own ~1e-6 relative accuracy
        tol = max(8 * float(jnp.finfo(jnp.dtype(pdt)).eps), 1e-5)
        for i in range(4):
            params, opt, _ = fused_step.fused_update(
                _tree(30 + i, scale=1.0), opt, params, cfg)
            for leaf_name in ("w_up", "w_in"):
                w = (params["blocks"]["mlp"] if leaf_name == "w_up"
                     else params["blocks"]["attn"])[leaf_name]
                if w.ndim < need:
                    continue
                nrm = _feasibility(w, levels)
                assert nrm <= radius * (1 + tol), \
                    f"{name}/{leaf_name} step {i + 1}: {nrm} > {radius}"
            if master:
                # the master copy tracks the PROJECTED params
                mw = opt["master"]["blocks"]["mlp"]["w_up"]
                assert _feasibility(mw, levels) <= radius * (1 + tol)

    def test_unmatched_leaves_untouched_by_projection(self):
        spec = ProjectionSpec(pattern=PATTERN, levels=BILEVEL, radius=0.1,
                              method="bisect")
        base = TrainConfig(lr=0.05, warmup=1, total_steps=20, master_dtype="")
        cfg = TrainConfig(**{**base.__dict__, "projection": spec})
        params = _tree(40, scale=2.0)
        opt = adamw.init(params, base)
        g = _tree(41)
        p_proj, _, _ = fused_step.fused_update(g, opt, params, cfg)
        p_plain, _, _ = adamw.update(g, opt, params, base)
        np.testing.assert_allclose(p_proj["blocks"]["mlp"]["w_down"],
                                   p_plain["blocks"]["mlp"]["w_down"],
                                   atol=1e-7)
        np.testing.assert_allclose(p_proj["emb"], p_plain["emb"], atol=1e-7)
