"""Sharded-vs-single-device equality for the schedule executor.

Two layers of coverage:

* ``TestShardedEqualsSingleDevice`` — in-process property tests on an
  8-device CPU mesh. They run when the interpreter was started with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the ``mesh`` CI
  job) and skip on a single-device host, where the flag can no longer be
  injected.
* ``TestShardedEqualitySubprocess`` — the same checks consolidated into one
  subprocess that forces the 8-device mesh itself, so the default (tier-1)
  suite exercises the executor on every run.
"""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

# legitimately environment-gated: XLA device count is fixed at interpreter
# start, so a 1-device tier-1 host CANNOT run these in-process (the subprocess
# class below covers the same checks there); the `mesh` CI job runs them.
multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]

# (name, shape, levels, spec entries) — >=3 distinct norm designs, trailing
# AND non-trailing sharded axes, even and uneven shards
DESIGNS = [
    ("l1inf_cols",     (32, 64), BILEVEL, (None, "model")),
    ("l1inf_rows",     (32, 64), BILEVEL, ("model", None)),
    ("l1infinf_last",  (4, 16, 64), TRILEVEL, (None, None, "model")),
    ("l1infinf_mid",   (4, 16, 64), TRILEVEL, (None, "model", None)),
    ("l12_rows",       (32, 48), [("2", 1), ("1", 1)], ("model", None)),
    ("l11_rows",       (32, 48), [("1", 1), ("1", 1)], ("model", None)),
    ("flat_l1",        (16, 24), [("1", 2)], ("model", None)),
    ("l1inf_uneven",   (32, 60), BILEVEL, (None, "model")),
    ("l11_uneven",     (30, 48), [("1", 1), ("1", 1)], ("model", None)),
]


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * 2, jnp.float32)


@multi_device
class TestShardedEqualsSingleDevice:
    @pytest.fixture(scope="class")
    def mesh(self):
        return jax.make_mesh((8,), ("model",))

    @pytest.mark.parametrize("name,shape,levels,spec", DESIGNS)
    def test_matches_single_device(self, mesh, name, shape, levels, spec):
        from repro.core import multilevel_project, multilevel_project_sharded
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        want = multilevel_project(y, levels, 2.5, method="sort")
        got = multilevel_project_sharded(y, levels, 2.5, mesh=mesh,
                                         spec=P(*spec))
        np.testing.assert_allclose(got, want, atol=1e-4)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    @pytest.mark.parametrize("spec", [(None, "model"), ("model", None)])
    def test_every_theta_solver_works_sharded(self, mesh, method, spec):
        # regression: filter's while_loop / bisect's fori_loop must survive
        # shard_map (replication-checker rejections) for BOTH sharded-axis
        # positions — ProjectionSpec defaults to bisect, auto may pick filter
        from repro.core import multilevel_project, multilevel_project_sharded
        y = _rand((32, 64), seed=6)
        want = multilevel_project(y, BILEVEL, 2.0, method=method)
        got = multilevel_project_sharded(y, BILEVEL, 2.0, mesh=mesh,
                                         spec=P(*spec), method=method)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_hook_projects_sharded_leaf_in_place(self, mesh):
        from jax.sharding import NamedSharding
        from repro.configs.types import ProjectionSpec
        from repro.optim.projection_hook import make_projection_hook
        W = _rand((3, 16, 64), seed=7)  # (layers, d, f): stacked batch axis
        pspec = P(None, None, "model")
        spec = ProjectionSpec(pattern=r"w_up", levels=(("inf", 1), ("1", 1)),
                              radius=1.0)  # method defaults to bisect
        plain = make_projection_hook(spec)
        meshy = make_projection_hook(spec, mesh=mesh,
                                     param_specs={"w_up": pspec})
        want = jax.jit(lambda p: plain(p, jnp.int32(0)))({"w_up": W})
        got = jax.jit(lambda p: meshy(p, jnp.int32(0)))(
            {"w_up": jax.device_put(W, NamedSharding(mesh, pspec))})
        np.testing.assert_allclose(jnp.asarray(got["w_up"]), want["w_up"],
                                   atol=1e-4)
        assert got["w_up"].sharding.spec == pspec  # projected in place

    def test_wrappers_and_auto(self, mesh):
        from repro.core import (make_sharded_bilevel, make_sharded_trilevel,
                                multilevel_project)
        y = _rand((32, 64), seed=1)
        got = make_sharded_bilevel(mesh, "model", method="auto")(y, 3.0)
        np.testing.assert_allclose(
            got, multilevel_project(y, BILEVEL, 3.0), atol=1e-4)
        y3 = _rand((4, 16, 64), seed=2)
        got3 = make_sharded_trilevel(mesh, "model", method="auto")(y3, 2.0)
        np.testing.assert_allclose(
            got3, multilevel_project(y3, TRILEVEL, 2.0), atol=1e-4)

    def test_uneven_shards_raise_in_specials(self, mesh):
        from repro.core import make_sharded_bilevel, make_sharded_trilevel
        with pytest.raises(ValueError, match="not divisible"):
            make_sharded_bilevel(mesh, "model")(jnp.zeros((4, 60)), 1.0)
        with pytest.raises(ValueError, match="not divisible"):
            make_sharded_trilevel(mesh, "model")(jnp.zeros((2, 4, 60)), 1.0)

    def test_batch_dims_with_sharded_batch_axis(self, mesh):
        from repro.core import multilevel_project, multilevel_project_sharded
        yb = _rand((8, 16, 40), seed=3)
        want = jax.vmap(lambda w: multilevel_project(w, BILEVEL, 1.5))(yb)
        got = multilevel_project_sharded(yb, BILEVEL, 1.5, mesh=mesh,
                                         spec=P("model", None, None),
                                         batch_dims=1)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_planner_routes_committed_sharded_arrays(self, mesh):
        from jax.sharding import NamedSharding
        from repro.core import multilevel_project, plan
        plan.clear_cache()
        y = _rand((64, 96), seed=4)
        ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
        p = plan.make_plan((64, 96), jnp.float32, BILEVEL, sharding=ys.sharding)
        assert p.key.sharding is not None
        assert "sharded" in p.timings_us
        want = multilevel_project(y, BILEVEL, 2.0)
        np.testing.assert_allclose(p(ys, 2.0), want, atol=1e-4)
        # method="auto" on the committed array takes the same mesh-aware plan
        np.testing.assert_allclose(
            multilevel_project(ys, BILEVEL, 2.0, method="auto"), want,
            atol=1e-4)

    def test_service_groups_by_sharding(self, mesh):
        from jax.sharding import NamedSharding
        from repro.serving import ProjectionService
        from repro.core import multilevel_project
        y = _rand((32, 64), seed=5)
        ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
        svc = ProjectionService(method="sort")
        t1 = svc.submit(y, BILEVEL, radius=1.0)
        t2 = svc.submit(ys, BILEVEL, radius=1.0)  # same shape, own plan key
        svc.flush()
        assert svc.stats["executed_batches"] == 2
        want = multilevel_project(y, BILEVEL, 1.0)
        np.testing.assert_allclose(svc.result(t1), want, atol=1e-5)
        np.testing.assert_allclose(svc.result(t2), want, atol=1e-4)


class TestShardedEqualitySubprocess:
    """Tier-1 coverage on single-device hosts: one subprocess forces the
    8-device mesh and replays the equality matrix (compiles are sub-second
    at these sizes, unlike the full train-step meshes in test_parallel)."""

    def test_equality_matrix(self):
        designs = [(n, s, lv, sp) for n, s, lv, sp in DESIGNS]
        prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # keep libtpu out of the child
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (make_sharded_bilevel, make_sharded_trilevel,
                        multilevel_project, multilevel_project_sharded, plan)

mesh = jax.make_mesh((8,), ("model",))
designs = {designs!r}
out = {{}}
for name, shape, levels, spec in designs:
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    y = jnp.asarray(rng.normal(size=shape) * 2, jnp.float32)
    want = multilevel_project(y, levels, 2.5, method="sort")
    got = multilevel_project_sharded(y, levels, 2.5, mesh=mesh, spec=P(*spec))
    out[name] = float(jnp.abs(got - want).max())

rng = np.random.default_rng(0)
y = jnp.asarray(rng.normal(size=(32, 64)) * 2, jnp.float32)
got = make_sharded_bilevel(mesh, "model", method="auto")(y, 3.0)
out["make_bilevel_auto"] = float(jnp.abs(
    got - multilevel_project(y, {BILEVEL!r}, 3.0)).max())
y3 = jnp.asarray(rng.normal(size=(4, 16, 64)) * 2, jnp.float32)
got3 = make_sharded_trilevel(mesh, "model", method="auto")(y3, 2.0)
out["make_trilevel_auto"] = float(jnp.abs(
    got3 - multilevel_project(y3, {TRILEVEL!r}, 2.0)).max())

try:
    make_sharded_bilevel(mesh, "model")(jnp.zeros((4, 60)), 1.0)
    out["uneven_error"] = "MISSING"
except ValueError as e:
    out["uneven_error"] = "not divisible" in str(e)

ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
p = plan.make_plan((32, 64), jnp.float32, {BILEVEL!r}, sharding=ys.sharding)
out["plan_sharded_key"] = p.key.sharding is not None
out["plan_diff"] = float(jnp.abs(
    p(ys, 2.0) - multilevel_project(y, {BILEVEL!r}, 2.0)).max())

# every registered theta-solver must survive shard_map, both axis positions
for method in ("sort", "bisect", "filter"):
    for spec in ((None, "model"), ("model", None)):
        want = multilevel_project(y, {BILEVEL!r}, 2.0, method=method)
        got = multilevel_project_sharded(y, {BILEVEL!r}, 2.0, mesh=mesh,
                                         spec=P(*spec), method=method)
        out[f"method_{{method}}_ax{{spec.index('model')}}"] = float(
            jnp.abs(got - want).max())

# the mesh-native hook path with ProjectionSpec's default method (bisect)
from repro.configs.types import ProjectionSpec
from repro.optim.projection_hook import make_projection_hook
W = jnp.asarray(rng.normal(size=(3, 16, 64)) * 2, jnp.float32)
pspec = P(None, None, "model")
hspec = ProjectionSpec(pattern=r"w_up", levels=(("inf", 1), ("1", 1)),
                       radius=1.0)
plain = make_projection_hook(hspec)
meshy = make_projection_hook(hspec, mesh=mesh, param_specs={{"w_up": pspec}})
want = jax.jit(lambda pr: plain(pr, jnp.int32(0)))({{"w_up": W}})["w_up"]
got = jax.jit(lambda pr: meshy(pr, jnp.int32(0)))(
    {{"w_up": jax.device_put(W, NamedSharding(mesh, pspec))}})["w_up"]
out["hook_sharded_leaf"] = float(jnp.abs(jnp.asarray(got) - want).max())
print("RESULT" + json.dumps(out))
"""
        res = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(prog)],
            capture_output=True, text=True, timeout=420,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu"})
        assert res.returncode == 0, res.stderr[-3000:]
        out = json.loads(res.stdout.split("RESULT", 1)[1])
        assert out.pop("uneven_error") is True
        assert out.pop("plan_sharded_key") is True
        for name, diff in out.items():
            assert diff < 1e-4, (name, diff)
