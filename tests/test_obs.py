"""Observability layer (repro.obs): metrics core semantics, exactness under
thread concurrency, exporter round-trips, the host-callback bridge's
trace-time-static gate, and profiler capture with the schedule-stage named
scopes actually present in the trace bytes."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import jax_bridge, metrics
from repro.obs import profile as obs_profile


@pytest.fixture()
def reg():
    """A fresh registry installed as the process-global one (the bridge and
    the planner mirror always write to the global)."""
    fresh = metrics.Registry()
    prev = metrics.set_registry(fresh)
    yield fresh
    metrics.set_registry(prev)


# ---------------------------------------------------------------- core model


class TestMetricsCore:
    def test_counter_inc_and_value(self, reg):
        c = reg.counter("c_total", "help")
        c.inc()
        c.inc(4)
        assert c.value == 5

    def test_counter_rejects_negative(self, reg):
        c = reg.counter("c_total")
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_gauge_set_add(self, reg):
        g = reg.gauge("g")
        g.set(2.5)
        g.add(-1.0)
        assert g.value == 1.5

    def test_labeled_children_are_cached(self, reg):
        c = reg.counter("req_total", labels=("route",))
        assert c.labels(route="a") is c.labels(route="a")
        assert c.labels(route="a") is not c.labels(route="b")

    def test_label_names_enforced(self, reg):
        c = reg.counter("req_total", labels=("route",))
        with pytest.raises(ValueError, match="expected labels"):
            c.labels(wrong="a")
        # a labeled family is not its own child
        with pytest.raises(ValueError, match="use .labels"):
            c.inc()

    def test_reregistration_same_signature_is_same_family(self, reg):
        a = reg.counter("x_total", "first", labels=("k",))
        b = reg.counter("x_total", "again", labels=("k",))
        assert a is b

    def test_reregistration_kind_mismatch_raises(self, reg):
        reg.counter("x_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.counter("x_total", labels=("k",))

    def test_histogram_counts_sum_and_overflow(self, reg):
        h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        assert h.count == 4
        assert h.sum == pytest.approx(6.05)
        snap = reg.snapshot()["h_seconds"]["values"][0]
        assert snap["counts"] == [1, 2, 1]   # per-bucket + the +Inf overflow

    def test_quantile_empty_and_interpolation(self, reg):
        h = reg.histogram("h_seconds", buckets=(1.0, 2.0, 4.0))
        assert h.quantile(0.5) == 0.0        # empty histogram
        for v in (0.5, 1.5, 3.0, 3.5):
            h.observe(v)
        # p50: rank 2 lands at the end of the (1,2] bucket
        assert 1.0 <= h.quantile(0.5) <= 2.0
        # values past the last bucket clamp to the last finite bound
        h.observe(100.0)
        assert h.quantile(1.0) == 4.0

    def test_timed_observes_on_exception(self, reg):
        h = reg.histogram("op_seconds", labels=("op",))
        with pytest.raises(RuntimeError):
            with metrics.timed(h, op="boom"):
                raise RuntimeError("boom")
        assert h.labels(op="boom").count == 1

    def test_clear_drops_families(self, reg):
        reg.counter("c_total").inc()
        reg.clear()
        assert reg.snapshot() == {}


# ------------------------------------------------------------- concurrency


class TestConcurrency:
    def test_eight_threads_exact(self, reg):
        """8 threads hammer one counter family and one histogram; counters
        are exact and the histogram conserves its total (the registry's
        single-lock design pins this)."""
        n_threads, n_iter = 8, 2000
        c = reg.counter("hits_total", labels=("t",))
        h = reg.histogram("lat_seconds", buckets=(1e-3, 1e-2, 1e-1))
        barrier = threading.Barrier(n_threads)

        def worker(tid):
            child = c.labels(t=str(tid % 4))     # contended label children
            barrier.wait()
            for i in range(n_iter):
                child.inc()
                h.observe((i % 7) * 1e-3)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        total = sum(ch.value for ch in c.children())
        assert total == n_threads * n_iter
        assert h.count == n_threads * n_iter
        snap = reg.snapshot()["lat_seconds"]["values"][0]
        assert sum(snap["counts"]) == snap["count"] == n_threads * n_iter
        expected_sum = n_threads * sum((i % 7) * 1e-3 for i in range(n_iter))
        assert snap["sum"] == pytest.approx(expected_sum, rel=1e-9)


# ---------------------------------------------------------------- exporters


class TestExporters:
    def _populate(self, reg):
        reg.counter("req_total", "requests", labels=("route",)) \
            .labels(route="submit").inc(3)
        reg.gauge("depth").set(7)
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)

    def test_jsonl_round_trip(self, reg, tmp_path):
        self._populate(reg)
        path = tmp_path / "metrics.jsonl"
        reg.write_jsonl(path)
        rows = [json.loads(line) for line in
                path.read_text().strip().splitlines()]
        by_name = {r["name"]: r for r in rows}
        assert by_name["req_total"]["value"] == 3
        assert by_name["req_total"]["labels"] == {"route": "submit"}
        assert by_name["depth"]["value"] == 7
        hist = by_name["lat_seconds"]
        assert hist["kind"] == "histogram"
        assert hist["count"] == 2 and sum(hist["counts"]) == 2
        assert hist["buckets"] == [0.1, 1.0]

    def test_prometheus_format(self, reg):
        self._populate(reg)
        text = reg.to_prometheus()
        assert "# TYPE req_total counter" in text
        assert '# HELP req_total requests' in text
        assert 'req_total{route="submit"} 3' in text
        assert "depth 7.0" in text
        # cumulative bucket counts, +Inf last, then sum/count
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert 'lat_seconds_bucket{le="1.0"} 2' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_count 2" in text

    def test_prometheus_label_escaping(self, reg):
        reg.counter("c_total", labels=("k",)).labels(k='a"b\\c\nd').inc()
        text = reg.to_prometheus()
        assert r'c_total{k="a\"b\\c\nd"} 1' in text

    def test_empty_registry_exports(self, reg):
        assert reg.to_jsonl() == ""
        assert reg.to_prometheus() == ""


# ------------------------------------------------------------------- bridge


class TestBridge:
    def test_gate_scope_restores(self):
        before = jax_bridge.enabled()
        with jax_bridge.enabled_scope(True):
            assert jax_bridge.enabled()
            with jax_bridge.enabled_scope(False):
                assert not jax_bridge.enabled()
            assert jax_bridge.enabled()
        assert jax_bridge.enabled() == before

    def test_disabled_gate_is_trace_time_static(self, reg):
        """With the bridge off at trace time the lowered program is
        bit-identical to one with no report() at all — the overhead-off
        claim in benchmarks/obs_overhead.py, pinned at HLO level."""

        def plain(x):
            return x * 2.0

        def instrumented(x):
            y = x * 2.0
            jax_bridge.report("bridge_gauge", jnp.sum(y))
            return y

        # same jit name so the lowered modules differ only in body
        instrumented.__name__ = plain.__name__
        x = jnp.arange(4.0)
        with jax_bridge.enabled_scope(False):
            a = jax.jit(plain).lower(x).as_text()
            b = jax.jit(instrumented).lower(x).as_text()
        assert a == b
        assert "bridge_gauge" not in reg.snapshot()

    def test_report_kinds_land_in_registry(self, reg):
        with jax_bridge.enabled_scope(True):
            @jax.jit
            def step(x):
                jax_bridge.report("b_gauge", jnp.max(x))
                jax_bridge.report("b_count", jnp.asarray(2.0),
                                  kind="counter")
                jax_bridge.report("b_hist", jnp.min(x), kind="hist",
                                  labels={"leaf": "w"})
                return x + 1

            jax.block_until_ready(step(jnp.arange(3.0)))
            jax.block_until_ready(step(jnp.arange(3.0)))
        jax.effects_barrier()
        assert reg.gauge("b_gauge").value == 2.0
        assert reg.counter("b_count").value == 4.0        # inc'd per call
        h = reg.histogram("b_hist", labels=("leaf",)).labels(leaf="w")
        assert h.count == 2 and h.sum == 0.0

    def test_report_bad_kind(self):
        with jax_bridge.enabled_scope(True):
            with pytest.raises(ValueError, match="unknown bridge kind"):
                jax_bridge.report("x", 1.0, kind="summary")

    def test_mark_pairs_into_histogram(self, reg):
        with jax_bridge.enabled_scope(True):
            @jax.jit
            def step(x):
                jax_bridge.mark("span_start")
                y = x @ x
                jax_bridge.mark("span_end")
                return y

            for _ in range(3):
                jax.block_until_ready(step(jnp.eye(8)))
        jax.effects_barrier()
        h = reg.histogram("span_seconds")
        assert h.count == 3
        assert h.sum >= 0.0

    def test_mark_name_validated(self):
        with jax_bridge.enabled_scope(True):
            with pytest.raises(ValueError, match="_start or _end"):
                jax_bridge.mark("span")

    def test_unmatched_end_dropped(self, reg):
        jax_bridge._mark_record("orphan_end", None)
        assert "orphan_seconds" not in reg.snapshot()


# ------------------------------------------------------------------ profile


class TestProfile:
    def test_stage_names(self):
        from repro.core import schedule as S

        sched = S.compile_schedule((4, 6), [("inf", 1), ("1", 1)])
        names = [obs_profile.stage_name(step, i)
                 for i, step in enumerate(sched.steps)]
        assert all(n.startswith("proj/") for n in names)
        assert any(n.startswith("proj/reduce") for n in names)
        assert any(n.startswith("proj/solve_") for n in names)
        assert any(n.startswith("proj/apply") for n in names)

    def test_stage_name_rejects_non_steps(self):
        with pytest.raises(TypeError, match="not a schedule step"):
            obs_profile.stage_name(object(), 0)

    def test_capture_disabled_is_noop(self, tmp_path):
        with obs_profile.capture("") as p:
            assert p is None
        with obs_profile.capture(None) as p:
            assert p is None

    def test_capture_trace_contains_stage_scopes(self, tmp_path):
        """End-to-end: run a jitted multilevel projection under capture();
        the .xplane.pb must contain the proj/* stage-scope names (named
        scopes survive into the lowered metadata and the trace bytes)."""
        from repro.core import multilevel

        x = jnp.asarray(np.random.default_rng(0).normal(size=(6, 10)),
                        jnp.float32)
        levels = [("inf", 1), ("1", 1)]
        fn = jax.jit(lambda v: multilevel.multilevel_project(
            v, levels, radius=1.0))
        jax.block_until_ready(fn(x))             # compile outside the trace
        trace_dir = tmp_path / "trace"
        with obs_profile.capture(trace_dir):
            jax.block_until_ready(fn(x))
        files = obs_profile.trace_files(trace_dir)
        assert files, "capture produced no artifacts"
        xplanes = [f for f in files if f.name.endswith(".xplane.pb")]
        assert xplanes, f"no .xplane.pb among {[f.name for f in files]}"
        blob = b"".join(f.read_bytes() for f in xplanes)
        assert b"proj/" in blob, "stage scopes missing from captured trace"


# ----------------------------------------------------- global registry wiring


def test_global_registry_swap_restores(reg):
    assert metrics.get_registry() is reg
    reg.counter("only_here_total").inc()
    assert "only_here_total" in metrics.get_registry().snapshot()
