"""Hypothesis property tests for repro.core projections.

Kept separate from test_core_projections.py for the randomized-vs-seeded
split. Without ``hypothesis`` installed (the seed container) the tests still
RUN through ``tests/_hypothesis_compat.py`` — a deterministic drop-in for the
subset of the API used here (CRC32-seeded examples, no shrinking); ``pip
install -e .[test]`` upgrades them to the real randomized search.
"""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container: deterministic fallback, tests still run
    from _hypothesis_compat import given, settings, st

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import core  # noqa: E402

jax.config.update("jax_enable_x64", False)

METHODS = core.available_methods()


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestL1Property:
    @given(
        n=st.integers(2, 60),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.05, 10.0),
        method=st.sampled_from(METHODS),
    )
    @settings(max_examples=30, deadline=None)
    def test_l1_property(self, n, seed, radius, method):
        y = _rand((n,), seed=seed, scale=4.0)
        x = core.project_l1(y, radius, method=method)
        n1 = float(jnp.sum(jnp.abs(x)))
        assert n1 <= radius * (1 + 1e-4) + 1e-5
        # projection never increases any coordinate's magnitude or flips sign
        assert bool(jnp.all(jnp.abs(x) <= jnp.abs(y) + 1e-6))
        assert bool(jnp.all(x * y >= -1e-6))

    @given(
        n=st.integers(2, 80),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.05, 10.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_filter_matches_sort(self, n, seed, radius):
        y = _rand((n,), seed=seed, scale=4.0)
        a = core.project_l1(y, radius, method="sort")
        b = core.project_l1(y, radius, method="filter")
        np.testing.assert_allclose(a, b, atol=1e-5)

    @given(
        n=st.integers(2, 40),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.05, 5.0),
        dup=st.integers(1, 4),
    )
    @settings(max_examples=25, deadline=None)
    def test_filter_matches_sort_with_ties(self, n, seed, radius, dup):
        # duplicated entries force ties at the threshold — the classic failure
        # mode of active-set filtering
        base = np.random.default_rng(seed).normal(size=n)
        y = jnp.asarray(np.repeat(base, dup), jnp.float32)
        a = core.project_l1(y, radius, method="sort")
        b = core.project_l1(y, radius, method="filter")
        np.testing.assert_allclose(a, b, atol=1e-5)

    @given(
        n=st.integers(2, 60),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.1, 5.0),
    )
    @settings(max_examples=20, deadline=None)
    def test_filter_idempotent(self, n, seed, radius):
        y = _rand((n,), seed=seed, scale=4.0)
        x = core.project_l1(y, radius, method="filter")
        x2 = core.project_l1(x, radius, method="filter")
        np.testing.assert_allclose(x, x2, atol=2e-6)


class TestExactProperty:
    @given(
        n=st.integers(1, 20),
        m=st.integers(1, 20),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.01, 20.0),
    )
    @settings(max_examples=15, deadline=None)
    def test_exact_property(self, n, m, seed, radius):
        y = _rand((n, m), seed=seed, scale=3.0)
        x = core.project_l1inf_exact(y, radius)
        assert float(core.l1inf_norm(x)) <= radius * (1 + 1e-3) + 1e-4
        if float(core.l1inf_norm(y)) <= radius:
            np.testing.assert_allclose(x, y, atol=1e-6)


class TestBilevelProperty:
    @given(
        n=st.integers(1, 24),
        m=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.05, 8.0),
        pq=st.sampled_from([(1, "inf"), (1, 1), (1, 2), (2, 1)]),
        method=st.sampled_from(METHODS),
    )
    @settings(max_examples=25, deadline=None)
    def test_bilevel_property(self, n, m, seed, radius, pq, method):
        p, q = pq
        y = _rand((n, m), seed=seed, scale=3.0)
        x = core.bilevel_project(y, radius, p=p, q=q, method=method)
        v = core.norm_reduce(x, q, axes=0)
        assert float(core.ball_norm(v, p, axis=-1)) <= radius * (1 + 2e-3) + 1e-4
        # idempotency (bi-level of a feasible point with same radius is identity
        # only when u >= v elementwise; feasibility implies it for p=1 norms)
        if p == 1:
            x2 = core.bilevel_project(x, radius, p=p, q=q, method=method)
            np.testing.assert_allclose(x, x2, atol=5e-3)


class TestMultilevelProperty:
    @given(
        dims=st.lists(st.integers(1, 8), min_size=2, max_size=4),
        seed=st.integers(0, 2**31 - 1),
        radius=st.floats(0.1, 5.0),
        method=st.sampled_from(METHODS),
    )
    @settings(max_examples=20, deadline=None)
    def test_multilevel_property(self, dims, seed, radius, method):
        y = _rand(tuple(dims), seed=seed, scale=2.0)
        levels = [(jnp.inf, 1)] * (len(dims) - 1) + [(1, 1)]
        x = core.multilevel_project(y, levels, radius, method=method)
        assert float(core.multilevel_norm(x, levels)) <= radius * (1 + 2e-3) + 1e-4
        assert bool(jnp.all(jnp.abs(x) <= jnp.abs(y) + 1e-6))
