"""Data pipeline determinism + end-to-end train-step behaviour on CPU."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.configs.types import ProjectionSpec, TrainConfig
from repro.core import l1inf_norm
from repro.data import (DataConfig, DataPipeline, classification_synthetic,
                        lung_like)
from repro.training import init_state, make_train_step


class TestDataPipeline:
    def test_deterministic_and_stateless(self):
        cfg = DataConfig(vocab=1000, seq_len=33, global_batch=8, microbatch=4)
        p1, p2 = DataPipeline(cfg), DataPipeline(cfg)
        np.testing.assert_array_equal(p1.batch(7), p2.batch(7))
        assert p1.batch(7).shape == (2, 4, 33)
        assert not np.array_equal(p1.batch(7), p1.batch(8))

    def test_restart_resumes_bit_exact(self):
        cfg = DataConfig(vocab=500, seq_len=16, global_batch=4, microbatch=4)
        pipe = DataPipeline(cfg)
        run1 = [pipe.batch(s) for s in range(10)]
        resumed = [DataPipeline(cfg).batch(s) for s in range(5, 10)]
        for a, b in zip(run1[5:], resumed):
            np.testing.assert_array_equal(a, b)

    def test_vocab_bounds(self):
        cfg = DataConfig(vocab=100, seq_len=64, global_batch=8, microbatch=8)
        b = DataPipeline(cfg).batch(0)
        assert b.min() >= 0 and b.max() < 100

    def test_classification_generator(self):
        x, y, info = classification_synthetic(n_samples=200, n_features=100,
                                              n_informative=16)
        assert x.shape == (200, 100) and set(np.unique(y)) <= {0, 1}
        # informative features carry signal: class-mean gap larger there
        gap = np.abs(x[y == 0].mean(0) - x[y == 1].mean(0))
        assert gap[info].mean() > 3 * np.delete(gap, info).mean()

    def test_lung_like_shapes(self):
        x, y, _ = lung_like(n_samples=100, n_features=64)
        assert x.shape == (100, 64)
        assert abs(float(x.mean())) < 0.1  # standardized


class TestTrainStep:
    def _run(self, arch="granite-3-2b", steps=3, **tkw):
        cfg = registry.smoke_config(arch)
        api = models.get(cfg)
        tcfg = TrainConfig(microbatch=2, total_steps=10, lr=1e-3, remat=False,
                           warmup=2, **tkw)
        state = init_state(cfg, tcfg, api, jax.random.PRNGKey(0))
        pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=17,
                                       global_batch=4, microbatch=2))
        step = jax.jit(make_train_step(cfg, tcfg, api, impl="naive"))
        out = []
        for i in range(steps):
            state, m = step(state, {"tokens": jnp.asarray(pipe.batch(i))})
            out.append({k: float(v) for k, v in m.items()})
        return state, out

    def test_loss_decreases_and_finite(self):
        _, ms = self._run(steps=6)
        assert all(np.isfinite(m["loss"]) for m in ms)
        assert ms[-1]["loss"] < ms[0]["loss"] + 0.1

    def test_projection_constraint_enforced(self):
        spec = ProjectionSpec(pattern=r"w_up|w_gate", radius=2.0, every=1)
        state, _ = self._run(steps=2, projection=spec)
        w = state["params"]["blocks"]["mlp"]["w_up"]
        for layer in range(w.shape[0]):
            assert float(l1inf_norm(w[layer])) <= 2.0 * (1 + 1e-3)

    def test_moe_train_step(self):
        _, ms = self._run(arch="deepseek-v3-671b", steps=2)
        assert all(np.isfinite(m["loss"]) for m in ms)

    def test_bf16_grad_accumulation(self):
        _, ms = self._run(steps=2, grad_allreduce_dtype="bfloat16",
                          master_dtype="")
        assert all(np.isfinite(m["loss"]) for m in ms)

    def test_int8_moments_train(self):
        _, ms = self._run(steps=3, moment_dtype="int8", master_dtype="")
        assert all(np.isfinite(m["loss"]) for m in ms)

    def test_determinism(self):
        _, a = self._run(steps=2)
        _, b = self._run(steps=2)
        assert a[-1]["loss"] == pytest.approx(b[-1]["loss"], abs=1e-6)
