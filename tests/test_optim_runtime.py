"""Optimizer, projection hook, checkpointing, fault-tolerance runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.types import ProjectionSpec, TrainConfig
from repro.optim import adamw
from repro.optim.projection_hook import (apply_projection, matched_names,
                                         project_tree, tree_sparsity)
from repro.runtime import (CheckpointManager, HeartbeatFile, StragglerMonitor,
                           run_with_restarts)


# -------------------------------------------------------------------- adamw
class TestAdamW:
    def _quad_losses(self, tcfg, steps=60):
        target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)),
                             jnp.float32)
        params = {"w": jnp.zeros((4, 256), jnp.float32)}
        opt = adamw.init(params, tcfg)
        loss_fn = lambda p: jnp.mean((p["w"] - target) ** 2)
        losses = []
        for _ in range(steps):
            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = adamw.update(g, opt, params, tcfg)
            losses.append(float(l))
        return losses

    def test_converges_on_quadratic(self):
        tcfg = TrainConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                           warmup=1, total_steps=60, master_dtype="")
        losses = self._quad_losses(tcfg)
        assert losses[-1] < 0.05 * losses[0]

    def test_int8_moments_converge(self):
        tcfg = TrainConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0, warmup=1,
                           total_steps=60, master_dtype="", moment_dtype="int8")
        losses = self._quad_losses(tcfg)
        assert losses[-1] < 0.1 * losses[0]

    def test_quantize_roundtrip(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 1000)),
                        jnp.float32)
        q = adamw.quantize_blockwise(x)
        xr = adamw.dequantize_blockwise(q, 1000)
        assert q["q"].dtype == jnp.int8
        # blockwise linear int8: error bounded by scale = max/127 per block
        err = np.abs(np.asarray(xr - x))
        bound = np.abs(np.asarray(x)).max() / 127 + 1e-6
        assert err.max() <= bound

    def test_schedule_warmup_and_decay(self):
        tcfg = TrainConfig(lr=1e-3, warmup=10, total_steps=100)
        assert float(adamw.lr_schedule(1, tcfg)) < 2e-4
        peak = float(adamw.lr_schedule(10, tcfg))
        assert peak == pytest.approx(1e-3, rel=1e-3)
        assert float(adamw.lr_schedule(100, tcfg)) < 2e-4

    def test_grad_clip(self):
        tcfg = TrainConfig(lr=0.0, grad_clip=1.0, master_dtype="")
        params = {"w": jnp.ones((8, 128))}
        opt = adamw.init(params, tcfg)
        g = {"w": jnp.full((8, 128), 100.0)}
        _, _, m = adamw.update(g, opt, params, tcfg)
        assert float(m["grad_norm"]) > 1000  # raw norm reported


# --------------------------------------------------------------- projection
class TestProjectionHook:
    def test_pattern_matching_and_feasibility(self):
        params = {"blocks": {"mlp": {"w_up": jnp.ones((4, 16, 32)),
                                     "w_down": jnp.ones((4, 32, 16))},
                             "ln": jnp.ones((4, 16))}}
        spec = ProjectionSpec(pattern=r"w_up", radius=2.0,
                              levels=(("inf", 1), (1, 1)))
        assert matched_names(params, spec) == ["blocks/mlp/w_up"]
        out = project_tree(params, spec)
        # each layer's (16, 32) matrix independently inside the ball
        norms = jnp.sum(jnp.max(jnp.abs(out["blocks"]["mlp"]["w_up"]), axis=1),
                        axis=-1)
        assert bool(jnp.all(norms <= 2.0 + 1e-4))
        np.testing.assert_allclose(out["blocks"]["mlp"]["w_down"],
                                   params["blocks"]["mlp"]["w_down"])

    def test_cadence(self):
        params = {"w_up": jnp.full((8, 8), 10.0)}
        spec = ProjectionSpec(pattern="w_up", radius=1.0, every=4)
        p_hit = apply_projection(params, spec, jnp.int32(8))
        p_miss = apply_projection(params, spec, jnp.int32(9))
        assert float(jnp.max(p_hit["w_up"])) < 10.0
        np.testing.assert_allclose(p_miss["w_up"], params["w_up"])

    def test_transpose_groups_rows(self):
        w = jnp.asarray(np.random.default_rng(2).normal(size=(20, 10)),
                        jnp.float32)
        spec = ProjectionSpec(pattern="w", radius=1.0, transpose=True,
                              levels=(("inf", 1), (1, 1)))
        out = project_tree({"w": w}, spec)["w"]
        # groups are rows: sum over rows of rowwise max
        assert float(jnp.sum(jnp.max(jnp.abs(out), axis=1))) <= 1.0 + 1e-4

    def test_auto_method_matches_fixed(self):
        # "auto" resolves per leaf workload at hook build; projected output
        # must agree with every fixed backend (they share the exact math)
        from repro.core import plan
        plan.clear_cache()
        w = jnp.asarray(np.random.default_rng(7).normal(size=(4, 12, 24)),
                        jnp.float32)
        params = {"w_up": w}
        want = project_tree(
            params, ProjectionSpec(pattern="w_up", radius=1.0,
                                   levels=(("inf", 1), (1, 1))))["w_up"]
        spec = ProjectionSpec(pattern="w_up", radius=1.0, method="auto",
                              levels=(("inf", 1), (1, 1)))
        out = project_tree(params, spec)["w_up"]
        np.testing.assert_allclose(out, want, atol=1e-5)
        # under jit (tracing): shape-only resolution must also work
        out_jit = jax.jit(lambda p: project_tree(p, spec))(params)["w_up"]
        np.testing.assert_allclose(out_jit, want, atol=1e-5)

    def test_auto_method_transpose(self):
        # the resolver's trailing-shape computation must mirror
        # _project_leaf's transpose (autotune the right vector length)
        from repro.core import plan
        plan.clear_cache()
        w = jnp.asarray(np.random.default_rng(8).normal(size=(20, 10)),
                        jnp.float32)
        spec = ProjectionSpec(pattern="w", radius=1.0, transpose=True,
                              method="auto", levels=(("inf", 1), (1, 1)))
        out = project_tree({"w": w}, spec)["w"]
        assert float(jnp.sum(jnp.max(jnp.abs(out), axis=1))) <= 1.0 + 1e-4

    def test_sparsity_report(self):
        params = {"w_up": jnp.concatenate(
            [jnp.zeros((8, 4)), jnp.ones((8, 4))], axis=1)}
        spec = ProjectionSpec(pattern="w_up", radius=1.0)
        sp = tree_sparsity(params, spec)
        assert sp["w_up"] == pytest.approx(50.0)


# ------------------------------------------------------------- checkpointing
class TestCheckpointing:
    def _state(self, v=1.0):
        return {"params": {"w": jnp.full((4, 8), v), "b": jnp.arange(3.0)},
                "opt": {"step": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(10, self._state(2.5), extra={"seed": 123})
        tree, manifest = mgr.restore()
        assert manifest["step"] == 10 and manifest["seed"] == 123
        np.testing.assert_allclose(tree["params"]["w"], 2.5)
        assert int(tree["opt"]["step"]) == 7

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state(float(s)))
        assert mgr.all_steps() == [3, 4]

    def test_async_and_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save_async(5, self._state())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, self._state())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2, 3):
            mgr.save(s, self._state(float(s)))
        tree, m = mgr.restore(step=2)
        np.testing.assert_allclose(tree["params"]["w"], 2.0)


# ---------------------------------------------------------------- resilience
class TestResilience:
    def test_straggler_detection(self):
        mon = StragglerMonitor(n_hosts=8, warn_factor=1.5, evict_factor=3.0,
                               min_samples=4)
        rep = None
        for step in range(10):
            times = {h: 1.0 for h in range(8)}
            times[3] = 4.0  # host 3 is 4x slower
            rep = mon.record(times)
        assert rep.stragglers == [3]
        assert rep.action == "evict"
        assert rep.worst_host == 3

    def test_no_false_positives(self):
        mon = StragglerMonitor(n_hosts=4)
        for _ in range(10):
            rep = mon.record({h: 1.0 + 0.01 * h for h in range(4)})
        assert rep.action == "none"

    def test_heartbeat(self, tmp_path):
        hb = HeartbeatFile(str(tmp_path), timeout=60)
        hb.beat(0)
        hb.beat(1)
        assert hb.dead_hosts(expected=3) == [2]

    def test_run_with_restarts(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        attempts = []

        def train(resume):
            attempts.append(resume)
            step = resume or 0
            while step < 30:
                step += 10
                mgr.save(step, {"s": jnp.int32(step)})
                if step == 20 and len(attempts) == 1:
                    raise RuntimeError("simulated host failure")
            return step

        final = run_with_restarts(train, mgr, max_restarts=2)
        assert final == 30
        assert attempts == [None, 20]  # restarted from the checkpoint

    def test_restart_gives_up(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1)

        def always_fail(resume):
            raise RuntimeError("dead node")

        with pytest.raises(RuntimeError):
            run_with_restarts(always_fail, mgr, max_restarts=2)
