"""Make the src/ layout importable without an editable install.

``pip install -e .[test]`` is the supported path (see pyproject.toml); this
shim keeps the historical ``PYTHONPATH=src python -m pytest`` invocation and
bare ``pytest`` working in environments without the install.
"""

import pathlib
import sys

_SRC = pathlib.Path(__file__).resolve().parent.parent / "src"
if _SRC.is_dir():
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, str(_SRC))
