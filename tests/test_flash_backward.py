"""Flash-attention backward (custom VJP) vs the chunked jnp oracle.

Acceptance (ISSUE 7): gradients of ``kernels.flash_attention`` match the
online-softmax oracle ``models.layers.attention_chunked`` on causal, windowed
and GQA configurations — including ragged block tails, because projected LM
training on TPU now differentiates *through* the Pallas kernel instead of
falling back to the jnp path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import attention_chunked

# (name, batch, hq, hkv, seq, d, causal, window)
CONFIGS = [
    ("causal",        2, 4, 4, 64, 16, True,  None),
    ("causal_ragged", 2, 4, 4, 40, 16, True,  None),
    ("windowed",      2, 4, 4, 64, 16, True,  12),
    ("gqa",           2, 4, 2, 48, 16, True,  None),
    ("gqa_windowed",  1, 8, 2, 40, 16, True,  9),
    ("noncausal",     2, 4, 4, 48, 16, False, None),
]


def _qkv(b, hq, hkv, s, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    return q, k, v


def _oracle(q, k, v, *, causal, window):
    # chunked oracle speaks (B, S, H, D); flash speaks (B, H, S, D).
    # sq == sk here, so the oracle's q_offset=0 matches flash's right-align.
    out = attention_chunked(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window, chunk=16)
    return out.transpose(0, 2, 1, 3)


class TestFlashBackward:
    @pytest.mark.parametrize("name,b,hq,hkv,s,d,causal,window", CONFIGS)
    def test_grads_match_chunked_oracle(self, name, b, hq, hkv, s, d, causal,
                                        window):
        q, k, v = _qkv(b, hq, hkv, s, d, seed=abs(hash(name)) % 2**31)
        cot = jnp.asarray(
            np.random.default_rng(7).normal(size=q.shape), jnp.float32)

        def loss_flash(q, k, v):
            return jnp.sum(flash_attention(
                q, k, v, causal=causal, window=window,
                block_q=16, block_k=16, interpret=True) * cot)

        def loss_ref(q, k, v):
            return jnp.sum(_oracle(q, k, v, causal=causal, window=window) * cot)

        got = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for g, w, nm in zip(got, want, "qkv"):
            np.testing.assert_allclose(g, w, atol=2e-4, rtol=1e-3,
                                       err_msg=f"d{nm} mismatch ({name})")

    @pytest.mark.parametrize("name,b,hq,hkv,s,d,causal,window", CONFIGS[:3])
    def test_value_unchanged_by_vjp_wrapper(self, name, b, hq, hkv, s, d,
                                            causal, window):
        q, k, v = _qkv(b, hq, hkv, s, d, seed=3)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=16, block_k=16, interpret=True)
        want = _oracle(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=1e-4)

    def test_grad_under_jit(self):
        q, k, v = _qkv(2, 4, 2, 48, 16, seed=5)

        def loss(q, k, v):
            return jnp.sum(flash_attention(
                q, k, v, causal=True, block_q=16, block_k=16,
                interpret=True) ** 2)

        got = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
        want = jax.grad(
            lambda q, k, v: jnp.sum(_oracle(q, k, v, causal=True,
                                            window=None) ** 2),
            argnums=(0, 1, 2))(q, k, v)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=2e-4, rtol=1e-3)

    def test_block_sweep_grads_agree(self):
        # the gradient must not depend on the blocking
        q, k, v = _qkv(1, 2, 2, 40, 16, seed=9)

        def loss(bq, bk):
            return jax.grad(lambda q: jnp.sum(flash_attention(
                q, k, v, causal=True, window=11, block_q=bq, block_k=bk,
                interpret=True) ** 2))(q)

        base = loss(16, 16)
        for bq, bk in [(8, 16), (16, 8), (40, 40)]:
            np.testing.assert_allclose(loss(bq, bk), base, atol=2e-4,
                                       rtol=1e-3)
