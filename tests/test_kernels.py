"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes as required for every kernel in repro.kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ball
from repro.kernels import ops, ref
from repro.kernels.bilevel_l1inf import (bilevel_l1inf_pallas, clip_pallas,
                                         colmax_pallas)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.l1ball import KERNEL_METHODS, project_l1_pallas
from repro.kernels.trilevel_l1infinf import trilevel_l1infinf_pallas


def _rand(shape, seed=0, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


_TOL = {jnp.float32: 1e-6, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------- bilevel parts
class TestColmaxKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "shape", [(8, 128), (256, 512), (300, 700), (1024, 257), (7, 1000), (1, 128)]
    )
    def test_matches_ref(self, shape, dtype):
        y = _rand(shape, seed=hash(shape) % 2**31, dtype=dtype, scale=3.0)
        got = colmax_pallas(y, interpret=True)
        want = ref.colmax_ref(y)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=_TOL[dtype])

    def test_block_shape_sweep(self):
        y = _rand((500, 900), seed=3, scale=2.0)
        want = ref.colmax_ref(y)
        for bn, bm in [(8, 128), (64, 256), (256, 512), (512, 1024)]:
            got = colmax_pallas(y, block_n=bn, block_m=bm, interpret=True)
            np.testing.assert_allclose(got, want, atol=1e-6)


class TestClipKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 128), (250, 333), (1024, 512)])
    def test_matches_ref(self, shape, dtype):
        y = _rand(shape, seed=11, dtype=dtype, scale=3.0)
        u = jnp.abs(_rand((shape[1],), seed=12, dtype=dtype))
        got = clip_pallas(y, u, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(ref.clip_ref(y, u), np.float32), atol=_TOL[dtype])


class TestL1BallKernel:
    @pytest.mark.parametrize("method", KERNEL_METHODS)
    @pytest.mark.parametrize("n", [16, 128, 129, 1000, 4096, 25600])
    @pytest.mark.parametrize("radius", [0.1, 1.0, 50.0])
    def test_matches_ref(self, n, radius, method):
        v = _rand((n,), seed=n, scale=2.0)
        got = project_l1_pallas(v, radius, method=method, interpret=True)
        want = ref.project_l1_ref(v, radius)
        np.testing.assert_allclose(got, want, atol=1e-5)
        assert float(jnp.sum(jnp.abs(got))) <= radius * (1 + 1e-4) + 1e-5

    @pytest.mark.parametrize("method", KERNEL_METHODS)
    def test_inside_ball_identity(self, method):
        v = _rand((256,), seed=5) * 1e-3
        got = project_l1_pallas(v, 1.0, method=method, interpret=True)
        np.testing.assert_allclose(got, v, atol=1e-7)

    def test_unknown_method_raises(self):
        v = _rand((128,), seed=6)
        with pytest.raises(ValueError, match="no pallas threshold kernel"):
            project_l1_pallas(v, 1.0, method="sort", interpret=True)


class TestFilterThresholdKernel:
    """Parity of the Michelot filter kernel against the exact sort backend."""

    @pytest.mark.parametrize("case", ["ties", "zeros", "feasible", "spike"])
    def test_adversarial_parity(self, case):
        rng = np.random.default_rng(21)
        v = {
            "ties": jnp.asarray(np.repeat(rng.normal(size=64), 4), jnp.float32),
            "zeros": jnp.asarray(
                np.concatenate([np.zeros(100), rng.normal(size=156)]), jnp.float32),
            "feasible": jnp.asarray(rng.normal(size=256) * 1e-4, jnp.float32),
            "spike": jnp.zeros((256,), jnp.float32).at[3].set(100.0),
        }[case]
        got = project_l1_pallas(v, 1.0, method="filter", interpret=True)
        want = ball.project_l1(v, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_matches_core_filter_backend(self):
        # kernel and jnp backend implement the same fixed point
        v = _rand((1000,), seed=33, scale=3.0)
        got = project_l1_pallas(v, 2.5, method="filter", interpret=True)
        want = ball.project_l1(v, 2.5, method="filter")
        np.testing.assert_allclose(got, want, atol=1e-6)


class TestBilevelFused:
    @pytest.mark.parametrize("shape", [(64, 128), (300, 700), (128, 25600 // 8)])
    @pytest.mark.parametrize("radius", [0.5, 5.0])
    def test_matches_oracle_and_core(self, shape, radius):
        y = _rand(shape, seed=7, scale=2.0)
        got = ops.bilevel_l1inf(y, radius, interpret=True, force=True)
        np.testing.assert_allclose(got, ref.bilevel_l1inf_ref(y, radius), atol=1e-5)
        # also against the core (sort-based) implementation
        from repro.core import bilevel
        np.testing.assert_allclose(got, bilevel.bilevel_l1inf(y, radius), atol=1e-4)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    def test_outer_method_selection(self, method):
        # every outer-step backend (incl. the jnp fallback for "sort") agrees
        y = _rand((300, 700), seed=9, scale=2.0)
        got = ops.bilevel_l1inf(y, 2.0, method=method, interpret=True, force=True)
        np.testing.assert_allclose(
            got, ref.bilevel_l1inf_ref(y, 2.0, method="sort"), atol=1e-5)

    @pytest.mark.parametrize("method", KERNEL_METHODS)
    def test_fused_wrapper(self, method):
        y = _rand((128, 256), seed=10, scale=2.0)
        got = bilevel_l1inf_pallas(y, 1.5, method=method, interpret=True)
        np.testing.assert_allclose(
            got, ref.bilevel_l1inf_ref(y, 1.5, method="sort"), atol=1e-5)

    def test_feasibility(self):
        y = _rand((256, 512), seed=8, scale=3.0)
        got = ops.bilevel_l1inf(y, 2.0, interpret=True, force=True)
        assert float(jnp.sum(jnp.max(jnp.abs(got), axis=0))) <= 2.0 * (1 + 1e-4)


class TestTrilevelFused:
    """Fused tri-level ℓ1,∞,∞ kernel vs the core.multilevel recursion."""

    @pytest.mark.parametrize("shape", [(2, 8, 128), (3, 17, 130), (8, 250, 64),
                                       (1, 64, 257)])
    @pytest.mark.parametrize("radius", [0.5, 2.0])
    def test_matches_oracle(self, shape, radius):
        y = _rand(shape, seed=hash(shape) % 2**31, scale=2.0)
        got = trilevel_l1infinf_pallas(y, radius, interpret=True)
        np.testing.assert_allclose(got, ref.trilevel_l1infinf_ref(y, radius),
                                   atol=1e-5)

    def test_reduce_pass_produces_both_aggregates(self):
        from repro.kernels.trilevel_l1infinf import trilevel_reduce_pallas
        y = _rand((4, 300, 700), seed=17, scale=2.0)
        v2, v1 = trilevel_reduce_pallas(y, interpret=True)
        np.testing.assert_allclose(v2, jnp.max(jnp.abs(y), axis=0), atol=1e-6)
        np.testing.assert_allclose(v1, jnp.max(jnp.abs(y), axis=(0, 1)),
                                   atol=1e-6)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    def test_outer_method_selection(self, method):
        # kernel θ-solvers and the jnp fallback ("sort") all agree
        y = _rand((3, 64, 200), seed=18, scale=2.0)
        got = ops.trilevel_l1infinf(y, 1.5, method=method, interpret=True,
                                    force=True)
        np.testing.assert_allclose(
            got, ref.trilevel_l1infinf_ref(y, 1.5, method="sort"), atol=1e-5)

    def test_block_shape_sweep(self):
        y = _rand((2, 500, 260), seed=19, scale=2.0)
        want = ref.trilevel_l1infinf_ref(y, 1.0)
        for bn, bm in [(8, 128), (64, 256), (256, 512), (512, 1024)]:
            got = trilevel_l1infinf_pallas(y, 1.0, block_n=bn, block_m=bm,
                                           interpret=True)
            np.testing.assert_allclose(got, want, atol=1e-5)

    def test_feasibility_and_dispatch(self):
        y = _rand((4, 100, 256), seed=20, scale=3.0)
        got = ops.trilevel_l1infinf(y, 2.0, interpret=True, force=True)
        from repro.core import multilevel
        lv = [(jnp.inf, 1), (jnp.inf, 1), (1, 1)]
        assert float(multilevel.multilevel_norm(got, lv)) <= 2.0 * (1 + 1e-4)
        # CPU (no force): the jnp oracle path
        np.testing.assert_allclose(ops.trilevel_l1infinf(y, 2.0),
                                   ref.trilevel_l1infinf_ref(y, 2.0), atol=1e-6)


# ------------------------------------------------------------- flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,hq,hkv,s,d",
        [(1, 1, 1, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 384, 128), (2, 2, 2, 257, 64)],
    )
    def test_causal_matches_ref(self, b, hq, hkv, s, d, dtype):
        q = _rand((b, hq, s, d), seed=1, dtype=dtype)
        k = _rand((b, hkv, s, d), seed=2, dtype=dtype)
        v = _rand((b, hkv, s, d), seed=3, dtype=dtype)
        got = flash_attention(q, k, v, causal=True, interpret=True)
        g = hq // hkv
        want = ref.flash_attention_ref(
            q, jnp.repeat(k, g, 1), jnp.repeat(v, g, 1), causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)

    def test_noncausal(self):
        q = _rand((1, 2, 256, 64), seed=4)
        k = _rand((1, 2, 256, 64), seed=5)
        v = _rand((1, 2, 256, 64), seed=6)
        got = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 128, 1000])
    def test_sliding_window(self, window):
        q = _rand((1, 2, 384, 64), seed=7)
        k = _rand((1, 2, 384, 64), seed=8)
        v = _rand((1, 2, 384, 64), seed=9)
        got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_cross_attention_rect(self):
        # encoder-decoder: kv longer than q
        q = _rand((1, 2, 128, 64), seed=10)
        k = _rand((1, 2, 512, 64), seed=11)
        v = _rand((1, 2, 512, 64), seed=12)
        got = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_block_size_sweep(self):
        q = _rand((1, 2, 512, 64), seed=13)
        k = _rand((1, 2, 512, 64), seed=14)
        v = _rand((1, 2, 512, 64), seed=15)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
            got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                                  interpret=True)
            np.testing.assert_allclose(got, want, atol=2e-5)
