"""Generated-backward correctness (kernels/codegen/backward.py).

The acceptance matrix of ISSUE 7: the 9-design matrix × {jit, vmap,
radius-cotangent} pins the generated residual VJP against the sort oracle's
Jacobian at 1e-5, a hypothesis sweep mirrors the forward coverage of
``tests/test_codegen.py``, and the executor-stub tests prove the backward
never re-executes the jnp schedule (the old custom-vjp recomputed through
``schedule.execute(method="sort")`` — the whole point of this backward is
that it doesn't).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multilevel, schedule
from repro.kernels import codegen
from repro.kernels.codegen.tiling import plan_tiles

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]

# kept in name-sync with tests/test_codegen.py / test_sharded_equality.py
DESIGNS = [
    ("l1inf_cols",     (32, 64), BILEVEL),
    ("l1inf_rows",     (32, 64), BILEVEL),
    ("l1infinf_last",  (4, 16, 64), TRILEVEL),
    ("l1infinf_mid",   (4, 16, 64), TRILEVEL),
    ("l12_rows",       (32, 48), [("2", 1), ("1", 1)]),
    ("l11_rows",       (32, 48), [("1", 1), ("1", 1)]),
    ("flat_l1",        (16, 24), [("1", 2)]),
    ("l1inf_uneven",   (32, 60), BILEVEL),
    ("l11_uneven",     (30, 48), [("1", 1), ("1", 1)]),
]

EXTRA_DESIGNS = [
    ("l111",          (3, 10, 20), [("1", 1), ("1", 1), ("1", 1)]),
    ("rank4_mixed",   (3, 4, 5, 32), [("inf", 1), ("2", 1), ("1", 1), ("1", 1)]),
    ("rank4_l2pair",  (2, 3, 4, 40), [("2", 2), ("inf", 1), ("1", 1)]),
    ("outer_l2",      (8, 16), [("inf", 1), ("2", 1)]),
    ("outer_inf",     (8, 16), [("1", 1), ("inf", 1)]),
    ("wide_groups",   (6, 200), [("1", 1), ("1", 1)]),
]

RADIUS = 1.5


def _rand(shape, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _gen_fn(shape, levels):
    sched = schedule.compile_schedule(shape, levels)
    return codegen.generate(sched, np.float32, interpret=True)


def _oracle_grad(y, levels, cot, radius=RADIUS):
    return jax.grad(lambda v: jnp.sum(multilevel.multilevel_project(
        v, levels, radius, method="sort") * cot))(y)


class TestGradParityMatrix:
    """9-design matrix (+extras) × {eager, jit, vmap, radius-cotangent}."""

    @pytest.mark.parametrize("name,shape,levels", DESIGNS + EXTRA_DESIGNS)
    def test_grad_matches_sort_oracle(self, name, shape, levels):
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        cot = _rand(shape, seed=abs(hash(name + "c")) % 2**31, scale=1.0)
        fn = _gen_fn(shape, levels)
        got = jax.grad(lambda v: jnp.sum(fn(v, RADIUS) * cot))(y)
        np.testing.assert_allclose(got, _oracle_grad(y, levels, cot),
                                   atol=1e-5)

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_grad_under_jit(self, name, shape, levels):
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        cot = _rand(shape, seed=abs(hash(name + "c")) % 2**31, scale=1.0)
        fn = _gen_fn(shape, levels)
        got = jax.jit(jax.grad(lambda v: jnp.sum(fn(v, RADIUS) * cot)))(y)
        np.testing.assert_allclose(got, _oracle_grad(y, levels, cot),
                                   atol=1e-5)

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_grad_under_vmap(self, name, shape, levels):
        ys = jnp.stack([_rand(shape, seed=100 + i) for i in range(3)])
        cots = jnp.stack([_rand(shape, seed=200 + i, scale=1.0)
                          for i in range(3)])
        fn = _gen_fn(shape, levels)
        vv = jax.vmap(lambda v, c: jnp.sum(fn(v, RADIUS) * c))
        got = jax.grad(lambda vs: jnp.sum(vv(vs, cots)))(ys)
        for i in range(3):
            np.testing.assert_allclose(
                got[i], _oracle_grad(ys[i], levels, cots[i]), atol=1e-5)

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_radius_cotangent(self, name, shape, levels):
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        fn = _gen_fn(shape, levels)
        g_gen = jax.grad(lambda r: jnp.sum(fn(y, r)))(jnp.float32(RADIUS))
        g_ref = jax.grad(lambda r: jnp.sum(multilevel.multilevel_project(
            y, levels, r, method="sort")))(jnp.float32(RADIUS))
        np.testing.assert_allclose(g_gen, g_ref, atol=1e-5)

    @pytest.mark.parametrize("radius", [0.25, 2.5, 1e6])
    def test_radius_regimes(self, radius):
        # fully-clipped, mixed, and identity (inside-ball) regimes
        y = _rand((12, 20), seed=11)
        cot = _rand((12, 20), seed=12, scale=1.0)
        fn = _gen_fn((12, 20), BILEVEL)
        got = jax.grad(lambda v: jnp.sum(fn(v, radius) * cot))(y)
        np.testing.assert_allclose(
            got, _oracle_grad(y, BILEVEL, cot, radius), atol=1e-5)


class TestNoExecutorReexecution:
    """The backward must not re-run the jnp schedule executor (acceptance:
    counted via a stub on ``schedule.execute``)."""

    def _stub(self, monkeypatch):
        calls = [0]
        real = schedule.execute

        def counting(*a, **k):
            calls[0] += 1
            return real(*a, **k)

        monkeypatch.setattr(schedule, "execute", counting)
        return calls

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_backward_never_calls_execute(self, name, shape, levels,
                                          monkeypatch):
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        fn = _gen_fn(shape, levels)   # build before stubbing (lru-cached)
        calls = self._stub(monkeypatch)
        val, grad = jax.value_and_grad(
            lambda v: jnp.sum(fn(v, RADIUS) ** 2))(y)
        jax.block_until_ready(grad)
        assert calls[0] == 0
        # and the pass actually produced a real gradient
        assert jnp.all(jnp.isfinite(grad)) and float(val) > 0

    def test_batched_backward_never_calls_execute(self, monkeypatch):
        sched = schedule.compile_schedule((8, 20), BILEVEL)
        fn = codegen.generate_batched(sched, np.float32, interpret=True)
        ys = jnp.stack([_rand((8, 20), seed=s, scale=3.0) for s in range(3)])
        radii = jnp.asarray([0.5, 1.5, 4.0], jnp.float32)
        calls = self._stub(monkeypatch)
        grad = jax.grad(lambda vs: jnp.sum(fn(vs, radii) ** 2))(ys)
        jax.block_until_ready(grad)
        assert calls[0] == 0

    def test_radius_cotangent_never_calls_execute(self, monkeypatch):
        y = _rand((10, 16), seed=7)
        fn = _gen_fn((10, 16), TRILEVEL[1:])
        calls = self._stub(monkeypatch)
        dr = jax.grad(lambda r: jnp.sum(fn(y, r)))(jnp.float32(1.5))
        jax.block_until_ready(dr)
        assert calls[0] == 0


class TestBatchedGradParity:
    """generate_batched: per-item radii cotangents + stacked grads."""

    BATCH_DESIGNS = [
        ("bilevel",  (8, 20),    BILEVEL),
        ("trilevel", (3, 9, 24), TRILEVEL),
        ("l12",      (6, 9),     [("2", 1), ("1", 1)]),
        ("flat_l1",  (40,),      [("1", 1)]),
        ("l1inf",    (5, 12),    [("1", 1), ("inf", 1)]),
    ]

    @pytest.mark.parametrize("name,shape,levels", BATCH_DESIGNS)
    def test_grad_and_radii_cotangent(self, name, shape, levels):
        sched = schedule.compile_schedule(shape, levels)
        fn = codegen.generate_batched(sched, np.float32, interpret=True)
        ys = jnp.stack([_rand(shape, seed=300 + s, scale=3.0)
                        for s in range(3)])
        radii = jnp.asarray([0.5, 1.5, 4.0], jnp.float32)

        def ref(ys, radii):
            return jnp.sum(jax.vmap(
                lambda y, r: multilevel.multilevel_project(
                    y, levels, r, method="sort"))(ys, radii) ** 2)

        gy, gr = jax.grad(lambda ys, rr: jnp.sum(fn(ys, rr) ** 2),
                          argnums=(0, 1))(ys, radii)
        wy, wr = jax.grad(ref, argnums=(0, 1))(ys, radii)
        np.testing.assert_allclose(gy, wy, atol=1e-4)
        np.testing.assert_allclose(gr, wr, atol=1e-4)


# --------------------------------------------------------------------------- #
# Hypothesis sweep mirroring the forward coverage of tests/test_codegen.py
# --------------------------------------------------------------------------- #

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - seed container has no hypothesis
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @st.composite
    def norm_designs(draw):
        rank = draw(st.integers(2, 4))
        shape = tuple(draw(st.lists(st.integers(1, 5), min_size=rank,
                                    max_size=rank)))
        n_levels = draw(st.integers(1, rank))
        cuts = sorted(draw(st.permutations(list(range(1, rank))))[:n_levels - 1])
        bounds = [0] + cuts + [rank]
        ks = [b - a for a, b in zip(bounds[:-1], bounds[1:])]
        levels = [(draw(st.sampled_from(["1", "2", "inf"])), k) for k in ks]
        return shape, levels

    class TestBackwardProperty:
        @given(design=norm_designs(), seed=st.integers(0, 2**31 - 1),
               radius=st.floats(0.05, 20.0))
        @settings(max_examples=25, deadline=None)
        def test_random_design_grad_matches_executor(self, design, seed,
                                                     radius):
            shape, levels = design
            if plan_tiles(schedule.compile_schedule(shape, levels),
                          np.float32) is None:
                return  # flat non-l1 designs: codegen declines, by design
            y = _rand(shape, seed=seed, scale=3.0)
            cot = _rand(shape, seed=seed + 1, scale=1.0)
            fn = _gen_fn(shape, levels)
            got = jax.grad(lambda v: jnp.sum(fn(v, radius) * cot))(y)
            want = _oracle_grad(y, levels, cot, radius)
            np.testing.assert_allclose(got, want, atol=1e-4)
