"""Mesh-parallel behaviour — runs in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single device (the dry-run flag must NOT be set globally)."""

import json
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8, timeout=420):
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
{textwrap.dedent(code)}
"""
    # JAX_PLATFORMS=cpu also in the env: with it unset, a host that has
    # libtpu installed stalls for minutes probing TPU instance metadata
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
class TestShardedProjection:
    """Subprocess mesh tests: minutes each on a single-core host (8 fake
    devices force full shard_map compiles). Nightly CI runs them; the default
    suite deselects via the ``slow`` marker."""

    def test_sharded_bilevel_matches_single_device(self):
        out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bilevel_l1inf
        from repro.core.sharded import make_sharded_bilevel
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        y = jnp.asarray(rng.normal(size=(64, 96)), jnp.float32)
        fn = make_sharded_bilevel(mesh, "model")
        got = jax.jit(fn)(y, 3.0)
        want = bilevel_l1inf(y, 3.0, method="sort")
        print("MAXDIFF", float(jnp.abs(got - want).max()))
        """)
        assert float(out.split("MAXDIFF")[1]) < 1e-4

    def test_sharded_trilevel_feasible(self):
        out = _run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.core.sharded import shard_map, trilevel_project_sharded
        from repro.core import multilevel_norm
        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(1)
        y = jnp.asarray(rng.normal(size=(4, 16, 64)), jnp.float32)
        body = functools.partial(trilevel_project_sharded, axis_name="model")
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(None, None, "model"), P()),
                       out_specs=P(None, None, "model"))
        got = jax.jit(fn)(y, jnp.float32(2.0))
        n = multilevel_norm(got, [("inf", 1), ("inf", 1), (1, 1)])
        print("NORM", float(n))
        """)
        assert float(out.split("NORM")[1]) <= 2.0 * (1 + 1e-3)

    def test_train_step_under_mesh_matches_single(self):
        out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import models
        from repro.configs import registry
        from repro.configs.types import TrainConfig, ProjectionSpec
        from repro.training import init_state, make_train_step
        from repro.models import params as PM
        from repro.parallel import sharding as SH
        from repro.data import DataPipeline, DataConfig

        cfg = registry.smoke_config("granite-3-2b")
        api = models.get(cfg)
        tcfg = TrainConfig(microbatch=4, total_steps=10, lr=1e-3, remat=False,
                           warmup=2)
        pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=17,
                                       global_batch=8, microbatch=4))
        batch = {"tokens": jnp.asarray(pipe.batch(0))}

        # single device
        state1 = init_state(cfg, tcfg, api, jax.random.PRNGKey(0))
        step1 = jax.jit(make_train_step(cfg, tcfg, api, impl="naive"))
        s1, m1 = step1(state1, batch)

        # 2x4 mesh with full sharding rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = SH.param_rules(mesh)
        specs = PM.param_specs(api.template(cfg), rules,
                               SH.mesh_shape_dict(mesh))
        state2 = init_state(cfg, tcfg, api, jax.random.PRNGKey(0))
        with mesh:
            state2 = {"params": jax.device_put(
                          state2["params"], SH.named(mesh, specs)),
                      "opt": state2["opt"]}
            step2 = jax.jit(make_train_step(cfg, tcfg, api, impl="naive",
                                            act_spec=P("data", None, None)))
            s2, m2 = step2(state2, batch)
        print("LOSSDIFF", abs(float(m1["loss"]) - float(m2["loss"])))
        w1 = s1["params"]["blocks"]["mlp"]["w_up"]
        w2 = s2["params"]["blocks"]["mlp"]["w_up"]
        print("WDIFF", float(jnp.abs(w1 - jnp.asarray(w2)).max()))
        """)
        assert float(out.split("LOSSDIFF")[1].split()[0]) < 5e-3
        assert float(out.split("WDIFF")[1]) < 5e-3

    def test_elastic_restore_to_smaller_mesh(self, tmp_path):
        out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.runtime import CheckpointManager

        mgr = CheckpointManager("{tmp_path}", keep=2)
        mesh8 = jax.make_mesh((8,), ("data",))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", None)))
        mgr.save(1, {{"x": x}})

        # restore onto a SHRUNK mesh (8 -> 4 data shards: elastic scale-down)
        mesh4 = jax.make_mesh((4,), ("data",))
        sh = {{"x": NamedSharding(mesh4, P("data", None))}}
        tree, _ = mgr.restore(shardings=sh)
        ok = np.allclose(np.asarray(tree["x"]), np.arange(64.0).reshape(8, 8))
        print("ELASTIC_OK", ok, len(tree["x"].sharding.device_set))
        """)
        assert "ELASTIC_OK True 4" in out


class TestRooflineParser:
    def test_collective_and_dot_parsing(self):
        from repro.roofline import hlo_parse
        hlo = """
HloModule test

%body (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %p = (s32[], f32[16,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,16] get-tuple-element(%p), index=1
  %d = f32[16,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[16,16] all-reduce(%d), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[16,16]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16] parameter(0)
  %init = (s32[], f32[16,16]) tuple(%c, %a)
  %w = (s32[], f32[16,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[16,16] get-tuple-element(%w), index=1
}
"""
        costs = hlo_parse.analyze_text(hlo)
        # dot: 2*16*16*16 = 8192 flops x 5 trips
        assert costs.flops == pytest.approx(8192 * 5)
        # all-reduce: 16*16*4 bytes * 2 (ring) * 5 trips
        assert costs.coll_bytes == pytest.approx(1024 * 2 * 5)

    def test_cell_skip_rules(self):
        from repro.configs import registry
        from repro.configs.types import SHAPES
        from repro.launch import specs as SP
        assert SP.cell_skipped(registry.get_arch("qwen3-32b"),
                               SHAPES["long_500k"])
        assert not SP.cell_skipped(registry.get_arch("zamba2-7b"),
                                   SHAPES["long_500k"])
        assert not SP.cell_skipped(registry.get_arch("qwen3-32b"),
                                   SHAPES["train_4k"])
