"""Deterministic stand-in for the ``hypothesis`` API used by the property
tests, so they RUN (instead of module-skipping) in containers without the
package installed.

Implements just the surface the tests use — ``given``, ``settings``, and the
``st.integers / floats / sampled_from / lists`` strategies. Each decorated
test is executed for a deterministic sample of examples: the RNG is seeded
from CRC32(test qualname, example index), so failures reproduce exactly
across runs and machines (no hypothesis-style shrinking, but also no flake).
``HYPOTHESIS_COMPAT_EXAMPLES`` caps examples per test (default 10) to keep
the tier-1 suite fast; with real hypothesis installed the tests import it
instead and this module is unused.
"""

from __future__ import annotations

import os
import zlib

import numpy as np

_EXAMPLE_CAP = int(os.environ.get("HYPOTHESIS_COMPAT_EXAMPLES", "10"))


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(min_value + (max_value - min_value) * rng.random()))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(size)]
        return _Strategy(sample)


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(**strategies_by_name):
    def deco(fn):
        import inspect

        n_examples = min(getattr(fn, "_compat_max_examples", 10), _EXAMPLE_CAP)

        def wrapper(*args, **fixtures):
            for i in range(n_examples):
                seed = zlib.crc32(f"{fn.__qualname__}:{i}".encode())
                rng = np.random.default_rng(seed)
                drawn = {k: s.sample(rng)
                         for k, s in strategies_by_name.items()}
                try:
                    fn(*args, **fixtures, **drawn)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__} "
                        f"example {i}): {drawn}") from e
            return None

        # expose only the NON-strategy params (self, pytest fixtures) so
        # pytest resolves those as fixtures and never sees the strategy
        # names (no functools.wraps — inspect.signature would follow
        # __wrapped__ back to the full parameter list).
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategies_by_name]
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


st = strategies
