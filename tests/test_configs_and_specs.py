"""Config registry, tuning knobs, and launch-spec plumbing."""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.types import SHAPES
from repro.launch import specs as SP


class TestRegistry:
    def test_all_assigned_archs_present(self):
        expected = {
            "stablelm-1.6b", "h2o-danube-1.8b", "granite-3-2b", "qwen3-32b",
            "whisper-large-v3", "deepseek-v3-671b", "kimi-k2-1t-a32b",
            "chameleon-34b", "xlstm-1.3b", "zamba2-7b",
        }
        assert set(registry.ASSIGNED) == expected

    def test_assignment_table_values(self):
        q = registry.get_arch("qwen3-32b")
        assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
                q.vocab) == (64, 5120, 64, 8, 25600, 151936)
        assert q.qk_norm
        d = registry.get_arch("deepseek-v3-671b")
        assert d.moe.n_experts == 256 and d.moe.top_k == 8
        assert d.mla is not None and d.d_model == 7168
        k = registry.get_arch("kimi-k2-1t-a32b")
        assert k.moe.n_experts == 384 and k.vocab == 163840
        z = registry.get_arch("zamba2-7b")
        assert z.n_layers == 81 and z.ssm.d_state == 64
        x = registry.get_arch("xlstm-1.3b")
        assert x.n_layers == 48 and x.d_ff == 0
        w = registry.get_arch("whisper-large-v3")
        assert w.n_enc_layers == 32 and w.vocab == 51866
        assert registry.get_arch("h2o-danube-1.8b").window == 4096

    def test_param_counts_match_names(self):
        # template-exact counts within tolerance of the advertised sizes
        from repro import models
        from repro.models import params as PM
        expect = {
            "stablelm-1.6b": 1.6e9, "h2o-danube-1.8b": 1.8e9,
            "granite-3-2b": 2.5e9, "qwen3-32b": 32e9,
            "deepseek-v3-671b": 671e9, "kimi-k2-1t-a32b": 1.04e12,
            "chameleon-34b": 34e9, "xlstm-1.3b": 1.3e9, "zamba2-7b": 7e9,
        }
        for name, n in expect.items():
            cfg = registry.get_arch(name)
            tot = PM.count_params(models.get(cfg).template(cfg))
            # xlstm block internals are slightly heavier than the official
            # 1.3B release (gated z-branch kept); see DESIGN.md §7
            hi = 1.6 if name == "xlstm-1.3b" else 1.55
            assert 0.6 * n <= tot <= hi * n, f"{name}: {tot:.3e} vs {n:.1e}"

    def test_per_arch_modules(self):
        from repro.configs import qwen3_32b, sae_paper
        assert qwen3_32b.CONFIG.name == "qwen3-32b"
        assert sae_paper.SMOKE.family == "sae"

    def test_smoke_configs_are_small(self):
        for name in registry.ASSIGNED:
            s = registry.smoke_config(name)
            assert s.d_model <= 128 and s.vocab <= 512


class TestTuning:
    def test_apply_tuning_moe_dispatch(self):
        cfg = registry.get_arch("kimi-k2-1t-a32b")
        tune = dataclasses.replace(SP.tuning_for(cfg), moe_dispatch="scatter")
        out = SP.apply_tuning(cfg, tune)
        assert out.moe.dispatch == "scatter"
        assert cfg.moe.dispatch == "einsum"  # original untouched

    def test_apply_tuning_xlstm(self):
        cfg = registry.get_arch("xlstm-1.3b")
        tune = dataclasses.replace(SP.tuning_for(cfg), xlstm_shard_r=True,
                                   xlstm_chunk=128)
        out = SP.apply_tuning(cfg, tune)
        assert out.xlstm.shard_r and out.xlstm.chunk == 128

    def test_giant_moes_get_quantized_moments(self):
        for name in ("deepseek-v3-671b", "kimi-k2-1t-a32b"):
            t = SP.tuning_for(registry.get_arch(name))
            assert t.moment_dtype == "int8" and t.master_dtype == ""

    def test_attn_tune_restored_default(self):
        from repro.models import layers as L
        cfg = registry.get_arch("stablelm-1.6b")
        SP.apply_tuning(cfg, SP.tuning_for(cfg))
        assert L.ATTN_TUNE["chunk"] == 1024
        assert L.ATTN_TUNE["probs_dtype"] is None


class TestShapes:
    def test_shape_table(self):
        assert SHAPES["train_4k"].global_batch == 256
        assert SHAPES["prefill_32k"].seq_len == 32768
        assert SHAPES["decode_32k"].kind == "decode"
        assert SHAPES["long_500k"].seq_len == 524288

    def test_40_cells_accounted(self):
        n_run = n_skip = 0
        for arch in registry.ASSIGNED:
            cfg = registry.get_arch(arch)
            for shape in SHAPES.values():
                if SP.cell_skipped(cfg, shape):
                    n_skip += 1
                else:
                    n_run += 1
        assert n_run + n_skip == 40
        assert n_skip == 7  # seven full-attention archs × long_500k
