"""The documentation is executable: every fenced ``python`` block in
docs/api.md and README.md runs top-to-bottom (blocks in one file share a
namespace), and the ``>>>`` examples in module docstrings pass doctest.
CI runs this file as its own job (see .github/workflows/ci.yml `docs`)."""

import doctest
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

# markdown files whose ```python blocks must execute cleanly, in order
EXECUTABLE_DOCS = ["docs/api.md", "docs/serving.md", "docs/sae.md",
                   "docs/observability.md", "README.md"]

# modules whose docstring ``>>>`` examples must pass (and exist)
DOCTEST_MODULES = ["repro.core.plan", "repro.obs.metrics"]
# modules doctested opportunistically (no examples required yet)
DOCTEST_OPTIONAL = ["repro.core.ball", "repro.core.multilevel",
                    "repro.core.bilevel", "repro.serving.engine",
                    "repro.serving.projection_service",
                    "repro.obs.jax_bridge", "repro.obs.profile"]

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def _blocks(relpath: str):
    text = (ROOT / relpath).read_text()
    return [(m.start(), m.group(1)) for m in _FENCE.finditer(text)]


@pytest.mark.parametrize("relpath", EXECUTABLE_DOCS)
def test_markdown_python_blocks_execute(relpath):
    blocks = _blocks(relpath)
    assert blocks, f"{relpath} has no ```python blocks"
    text = (ROOT / relpath).read_text()
    ns = {}
    for start, code in blocks:
        line = text.count("\n", 0, start) + 1
        try:
            exec(compile(code, f"{relpath}:{line}", "exec"), ns)  # noqa: S102
        except Exception as e:  # pragma: no cover - the assertion IS the test
            raise AssertionError(
                f"{relpath} block at line {line} failed: {e!r}") from e


@pytest.mark.parametrize("modname", DOCTEST_MODULES + DOCTEST_OPTIONAL)
def test_module_doctests(modname):
    mod = __import__(modname, fromlist=["_"])
    results = doctest.testmod(mod, verbose=False)
    assert results.failed == 0, f"{modname}: {results.failed} doctest failures"
    if modname in DOCTEST_MODULES:
        assert results.attempted > 0, f"{modname} lost its doctest examples"
