"""Generated-kernel correctness (kernels/codegen): the 9-design equality
matrix vs the schedule executor, golden pinning against the hand-written
fused kernels, tiling eligibility, planner/autotune integration, reverse-mode
grad parity, and the planner-routed ops dispatch (use_pallas on the input's
device + REPRO_FORCE_INTERPRET).

The hypothesis sweep at the bottom (random rank-2–4 mixed ℓ1/ℓ2/ℓ∞ designs)
runs wherever ``hypothesis`` is installed (``pip install -e .[test]``; the
``codegen`` CI job) and skips cleanly elsewhere — the deterministic matrix
above it covers the same ground on fixed seeds either way.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multilevel, plan, schedule
from repro.kernels import codegen, ops, ref
from repro.kernels.bilevel_l1inf import bilevel_l1inf_pallas
from repro.kernels.codegen.tiling import plan_tiles
from repro.kernels.trilevel_l1infinf import trilevel_l1infinf_pallas

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]

# the 9-design matrix of tests/test_sharded_equality.py (unsharded view —
# kept in sync by name so the acceptance criterion reads across both files)
DESIGNS = [
    ("l1inf_cols",     (32, 64), BILEVEL),
    ("l1inf_rows",     (32, 64), BILEVEL),
    ("l1infinf_last",  (4, 16, 64), TRILEVEL),
    ("l1infinf_mid",   (4, 16, 64), TRILEVEL),
    ("l12_rows",       (32, 48), [("2", 1), ("1", 1)]),
    ("l11_rows",       (32, 48), [("1", 1), ("1", 1)]),
    ("flat_l1",        (16, 24), [("1", 2)]),
    ("l1inf_uneven",   (32, 60), BILEVEL),
    ("l11_uneven",     (30, 48), [("1", 1), ("1", 1)]),
]

# beyond the matrix: higher rank, multi-axis levels, every outer-solve norm
EXTRA_DESIGNS = [
    ("l111",          (3, 10, 20), [("1", 1), ("1", 1), ("1", 1)]),
    ("rank4_mixed",   (3, 4, 5, 32), [("inf", 1), ("2", 1), ("1", 1), ("1", 1)]),
    ("rank4_l2pair",  (2, 3, 4, 40), [("2", 2), ("inf", 1), ("1", 1)]),
    ("outer_l2",      (8, 16), [("inf", 1), ("2", 1)]),
    ("outer_inf",     (8, 16), [("1", 1), ("inf", 1)]),
    ("wide_groups",   (6, 200), [("1", 1), ("1", 1)]),      # resident θ-solve
]


def _rand(shape, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestCodegenEqualsExecutor:
    @pytest.mark.parametrize("name,shape,levels", DESIGNS + EXTRA_DESIGNS)
    @pytest.mark.parametrize("radius", [0.0, 2.5, 1e6])
    def test_matches_schedule_executor(self, name, shape, levels, radius):
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        want = multilevel.multilevel_project(y, levels, radius, method="sort")
        got = codegen.codegen_project(y, levels, radius, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-4)
        # feasibility: the fixed-budget bisection leaves ~1-ulp-of-max
        # residuals per element (same as the jnp bisect backend), which an
        # l1-heavy norm SUMS — allow that, the allclose above is the tight pin
        nrm = float(multilevel.multilevel_norm(got, levels))
        assert nrm <= radius * (1 + 1e-4) + 3e-7 * got.size * float(
            jnp.abs(y).max() + 1.0)

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_plan_codegen_backend(self, name, shape, levels):
        # acceptance: every matrix design is selectable through the planner
        p = plan.make_plan(shape, jnp.float32, levels, method="codegen",
                           interpret=True)
        y = _rand(shape, seed=abs(hash(name)) % 2**31)
        want = multilevel.multilevel_project(y, levels, 2.5, method="sort")
        np.testing.assert_allclose(p(y, 2.5), want, atol=1e-4)

    @pytest.mark.parametrize("name,shape,levels", DESIGNS)
    def test_auto_offers_codegen(self, name, shape, levels):
        # under method="auto" the generated kernel competes (and CAN win)
        p = plan.make_plan(shape, jnp.float32, levels, method="auto",
                           interpret=True)
        assert "codegen" in p.timings_us

    def test_ties_at_the_max(self):
        y = jnp.asarray([[2.0, 2.0, -2.0], [2.0, -2.0, 2.0]], jnp.float32)
        got = codegen.codegen_project(y, BILEVEL, 1.0, interpret=True)
        want = multilevel.multilevel_project(y, BILEVEL, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    def test_outer_method_selection(self, method):
        y = _rand((24, 40), seed=3)
        got = codegen.codegen_project(y, BILEVEL, 1.5, method=method,
                                      interpret=True)
        want = multilevel.multilevel_project(y, BILEVEL, 1.5, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_batch_dims_schedule(self):
        # a batch_dims schedule lowers as vmaps of the batch-free kernel
        sched = schedule.compile_schedule((3, 8, 16), BILEVEL, batch_dims=1)
        fn = codegen.generate(sched, np.float32, interpret=True)
        yb = _rand((3, 8, 16), seed=4)
        want = jax.vmap(
            lambda w: multilevel.multilevel_project(w, BILEVEL, 1.5))(yb)
        np.testing.assert_allclose(fn(yb, 1.5), want, atol=1e-5)

    def test_batch_radius_kind_plan(self):
        ys = jnp.stack([_rand((8, 16), seed=s) for s in range(3)])
        radii = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)
        p = plan.make_plan((8, 16), jnp.float32, BILEVEL,
                           radius_kind="batch", method="codegen",
                           interpret=True)
        out = p(ys, radii)
        for i in range(3):
            want = multilevel.multilevel_project(ys[i], BILEVEL, radii[i],
                                                 method="sort")
            np.testing.assert_allclose(out[i], want, atol=1e-5)


class TestGoldenReferences:
    """The demoted hand-written kernels pin the generated ones exactly: same
    structure, same outer solver, same block layout defaults."""

    @pytest.mark.parametrize("shape", [(64, 128), (300, 700), (16, 130)])
    def test_bilevel_pinned(self, shape):
        y = _rand(shape, seed=hash(shape) % 2**31)
        got = codegen.codegen_project(y, BILEVEL, 2.0, interpret=True)
        want = bilevel_l1inf_pallas(y, 2.0, method="bisect", interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    @pytest.mark.parametrize("shape", [(2, 8, 128), (3, 17, 130), (8, 250, 64)])
    def test_trilevel_pinned(self, shape):
        y = _rand(shape, seed=hash(shape) % 2**31)
        got = codegen.codegen_project(y, TRILEVEL, 2.0, interpret=True)
        want = trilevel_l1infinf_pallas(y, 2.0, method="bisect",
                                        interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)


class TestTiling:
    def test_canonical_metadata(self):
        sched = schedule.compile_schedule((2, 3, 4, 5), [("2", 2), ("inf", 1),
                                                         ("1", 1)])
        assert sched.level_group_sizes == (6, 4)
        assert sched.canonical_shape == (6, 4, 5)
        assert sched.canonical_stage_shapes == ((6, 4, 5), (4, 5), (5,))

    def test_resident_pin_for_l1_apply(self):
        sched = schedule.compile_schedule((32, 48), [("1", 1), ("1", 1)])
        tp = plan_tiles(sched, np.float32)
        assert tp.n_resident and tp.block_n == 32

    def test_blocks_shrink_to_fit_vmem(self):
        sched = schedule.compile_schedule((64, 2048, 512), TRILEVEL)
        tp = plan_tiles(sched, np.float32)
        assert tp is not None and tp.block_n < 2048
        from repro.kernels.codegen.tiling import VMEM_BUDGET_BYTES
        assert tp.vmem_bytes <= VMEM_BUDGET_BYTES

    def test_oversized_resident_group_rejected(self):
        # an l1 apply over a 2M-row axis cannot be VMEM-resident
        sched = schedule.compile_schedule((2_000_000, 128),
                                          [("1", 1), ("1", 1)])
        assert plan_tiles(sched, np.float32) is None
        assert not codegen.supported((2_000_000, 128),
                                     (("1", 1), ("1", 1)), np.float32)

    def test_flat_non_l1_rejected(self):
        sched = schedule.compile_schedule((16, 24), [("2", 2)])
        assert plan_tiles(sched, np.float32) is None


class TestGradParity:
    def test_bilevel_grad_matches_sort_oracle(self):
        y = _rand((12, 20), seed=5)
        cot = _rand((12, 20), seed=6, scale=1.0)

        def loss_gen(v):
            return jnp.sum(codegen.codegen_project(
                v, BILEVEL, 1.5, interpret=True) * cot)

        def loss_ref(v):
            return jnp.sum(multilevel.multilevel_project(
                v, BILEVEL, 1.5, method="sort") * cot)

        np.testing.assert_allclose(jax.grad(loss_gen)(y),
                                   jax.grad(loss_ref)(y), atol=1e-5)

    def test_radius_cotangent(self):
        y = _rand((10, 16), seed=7)
        g_gen = jax.grad(lambda r: jnp.sum(codegen.codegen_project(
            y, BILEVEL, r, interpret=True)))(jnp.float32(1.5))
        g_ref = jax.grad(lambda r: jnp.sum(multilevel.multilevel_project(
            y, BILEVEL, r, method="sort")))(jnp.float32(1.5))
        np.testing.assert_allclose(g_gen, g_ref, atol=1e-5)


class TestOpsDispatch:
    def test_use_pallas_gates_on_input_device(self):
        y = _rand((4, 8), seed=8)
        on_tpu = jax.devices()[0].platform == "tpu"
        assert ops.use_pallas(y) is on_tpu   # committed device of the input
        assert ops.use_pallas() is on_tpu    # default backend device

        # a tracer has no committed device: falls back to the default
        def traced(v):
            assert ops.use_pallas(v) is on_tpu
            return v

        np.testing.assert_allclose(jax.jit(traced)(y), y)

    def test_force_interpret_env(self, monkeypatch):
        y = _rand((16, 32), seed=9)
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        assert ops.force_interpret()
        # kernel debugging on CPU without threading interpret=True by hand
        got = ops.bilevel_l1inf(y, 2.0, force=True)
        np.testing.assert_allclose(got, ref.bilevel_l1inf_ref(y, 2.0),
                                   atol=1e-5)
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
        assert not ops.force_interpret()

    def test_cpu_path_routes_through_planner(self, monkeypatch):
        y = _rand((16, 32), seed=10)
        # the planner jnp schedule is the off-TPU branch of the dispatch — pin
        # it on every platform (no skip on TPU: the branch exists there too)
        monkeypatch.setattr(ops, "use_pallas", lambda *_a, **_k: False)
        got = ops.bilevel_l1inf(y, 2.0, method="filter")
        np.testing.assert_allclose(
            got, ref.bilevel_l1inf_ref(y, 2.0, method="filter"), atol=1e-6)
        key = plan.PlanKey((16, 32), "float32", (("inf", 1), ("1", 1)),
                           "scalar", jax.devices()[0].platform)
        assert (key, "filter", False) in plan._PLANS


# --------------------------------------------------------------------------- #
# Hypothesis sweep: random valid norm designs, rank 2-4, mixed l1/l2/linf
# --------------------------------------------------------------------------- #

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - seed container has no hypothesis
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @st.composite
    def norm_designs(draw):
        rank = draw(st.integers(2, 4))
        shape = tuple(draw(st.lists(st.integers(1, 5), min_size=rank,
                                    max_size=rank)))
        n_levels = draw(st.integers(1, rank))
        # split `rank` axes into n_levels positive parts
        cuts = sorted(draw(st.permutations(list(range(1, rank))))[:n_levels - 1])
        bounds = [0] + cuts + [rank]
        ks = [b - a for a, b in zip(bounds[:-1], bounds[1:])]
        levels = [(draw(st.sampled_from(["1", "2", "inf"])), k) for k in ks]
        return shape, levels

    class TestCodegenProperty:
        @given(design=norm_designs(), seed=st.integers(0, 2**31 - 1),
               radius=st.floats(0.05, 20.0))
        @settings(max_examples=25, deadline=None)
        def test_random_design_matches_executor(self, design, seed, radius):
            shape, levels = design
            if plan_tiles(schedule.compile_schedule(shape, levels),
                          np.float32) is None:
                return  # flat non-l1 designs: codegen declines, by design
            y = _rand(shape, seed=seed, scale=3.0)
            want = multilevel.multilevel_project(y, levels, radius,
                                                 method="sort")
            got = codegen.codegen_project(y, levels, radius, interpret=True)
            np.testing.assert_allclose(got, want, atol=1e-4)


class TestBatchedCodegen:
    """The batched-grid serving lowering (generate_batched): the stacked
    batch axis joins the Pallas grid with per-item radii in SMEM, instead of
    vmap-lifting the per-item kernel."""

    BATCH_DESIGNS = [
        ("bilevel",  (8, 20),    BILEVEL),
        ("trilevel", (3, 9, 24), TRILEVEL),
        ("l12",      (6, 9),     [("2", 1), ("1", 1)]),
        ("flat_l1",  (40,),      [("1", 1)]),
        ("l1inf",    (5, 12),    [("1", 1), ("inf", 1)]),
    ]

    @pytest.mark.parametrize("name,shape,levels", BATCH_DESIGNS)
    @pytest.mark.parametrize("batch", [1, 3, 4])
    def test_matches_per_item_executor(self, name, shape, levels, batch):
        sched = schedule.compile_schedule(shape, levels)
        fn = codegen.generate_batched(sched, np.float32, interpret=True)
        ys = jnp.stack([_rand(shape, seed=100 * batch + i, scale=3.0)
                        for i in range(batch)])
        radii = jnp.asarray([0.5 + 0.75 * i for i in range(batch)],
                            jnp.float32)
        out = fn(ys, radii)
        for i in range(batch):
            want = multilevel.multilevel_project(ys[i], levels, radii[i],
                                                 method="sort")
            np.testing.assert_allclose(out[i], want, atol=1e-4)

    def test_gradient_matches_vmap_executor(self):
        sched = schedule.compile_schedule((8, 20), BILEVEL)
        fn = codegen.generate_batched(sched, np.float32, interpret=True)
        ys = jnp.stack([_rand((8, 20), seed=s, scale=3.0) for s in range(3)])
        radii = jnp.asarray([0.5, 1.5, 4.0], jnp.float32)

        def ref_loss(ys):
            out = jax.vmap(lambda y, r: multilevel.multilevel_project(
                y, BILEVEL, r, method="sort"))(ys, radii)
            return jnp.sum(out ** 2)

        g_got = jax.grad(lambda ys: jnp.sum(fn(ys, radii) ** 2))(ys)
        g_want = jax.grad(ref_loss)(ys)
        np.testing.assert_allclose(g_got, g_want, atol=1e-4)

    def test_rejects_wrong_rank_and_radii(self):
        sched = schedule.compile_schedule((8, 20), BILEVEL)
        fn = codegen.generate_batched(sched, np.float32, interpret=True)
        ys = jnp.stack([_rand((8, 20), seed=s) for s in range(2)])
        with pytest.raises(ValueError):
            fn(ys[0], jnp.asarray([1.0], jnp.float32))  # missing batch axis
        with pytest.raises(ValueError):
            fn(ys, jnp.asarray([1.0, 2.0, 3.0], jnp.float32))  # radii len

    def test_rejects_batch_dims_schedule(self):
        sched = schedule.compile_schedule((3, 8, 16), BILEVEL, batch_dims=1)
        with pytest.raises(ValueError):
            codegen.generate_batched(sched, np.float32, interpret=True)

    def test_codegen_batch_plan_backend(self):
        # the serving route: codegen_batch through the planner on a
        # radius_kind="batch" key, one batched-grid dispatch for the bucket
        ys = jnp.stack([_rand((8, 16), seed=s) for s in range(4)])
        radii = jnp.asarray([0.5, 1.0, 2.0, 3.0], jnp.float32)
        p = plan.make_plan((8, 16), jnp.float32, BILEVEL,
                           radius_kind="batch", method="codegen_batch",
                           interpret=True)
        out = p(ys, radii)
        for i in range(4):
            want = multilevel.multilevel_project(ys[i], BILEVEL, radii[i],
                                                 method="sort")
            np.testing.assert_allclose(out[i], want, atol=1e-5)

    def test_codegen_batch_rejected_on_scalar_key(self):
        # batch-native: a scalar-radius plan key must not offer it
        with pytest.raises(ValueError, match="not available"):
            plan.make_plan((8, 16), jnp.float32, BILEVEL,
                           method="codegen_batch", interpret=True)

    def test_auto_offers_codegen_batch_on_batch_keys(self):
        p = plan.make_plan((8, 16), jnp.float32, BILEVEL,
                           radius_kind="batch", method="auto",
                           interpret=True)
        assert "codegen_batch" in p.timings_us
