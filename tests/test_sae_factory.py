"""The sparse-SAE training factory, locked down.

* property tests (hypothesis, or the deterministic ``_hypothesis_compat``
  fallback in the seed container): harvest round-trip — shapes/dtypes/layer
  selection survive shard-write → ``DataPipeline``-read — and the MMCS
  invariants (self-similarity, permutation/sign invariance, symmetry).
* a deterministic tiny-config regression for ``benchmarks/sae_tables`` that
  pins test accuracy and first-layer column sparsity for all 5 methods.
* a miniature end-to-end factory run (harvest → projected SAE training →
  MMCS) asserting the per-step constraint actually holds on the result.
* GSP whole-network sparsification through the mesh executor on a forced
  8-device CPU mesh (subprocess — device count is fixed at startup).
"""

import json
import re
import subprocess
import sys

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container: deterministic fallback, tests still run
    from _hypothesis_compat import given, settings, st

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, DataPipeline
from repro.data.activations import ActivationReader, read_meta
from repro.training import sae_factory as F
from repro.training.mmcs import mmcs, mmcs_sym, mmcs_table


FCFG = F.SAEFactoryConfig(layers=(0, 2), harvest_steps=3, seq_len=8,
                          lm_batch=2, train_steps=6, sae_batch=16,
                          microbatch=8, expansion=2, radius=0.2)


@pytest.fixture(scope="module")
def harvest_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("harvest")
    meta = F.harvest_activations(FCFG, d)
    return d, meta


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ----------------------------------------------------------- harvest round-trip
class TestHarvestRoundTrip:
    def test_meta(self, harvest_dir):
        d, meta = harvest_dir
        assert meta["layers"] == [0, 2]
        assert meta["site"] == "resid"
        assert meta["rows_per_shard"] == FCFG.lm_batch * FCFG.seq_len
        assert meta["n_shards"] == FCFG.harvest_steps
        assert read_meta(d) == meta

    def test_only_selected_layers_on_disk(self, harvest_dir):
        d, _ = harvest_dir
        layers = sorted({int(m.group(1)) for p in d.glob("layer*_shard*.npy")
                         for m in [re.match(r"layer(\d+)_shard", p.name)]})
        assert layers == [0, 2]

    @given(step=st.integers(0, 40), layer=st.sampled_from([0, 2]))
    @settings(max_examples=10, deadline=None)
    def test_reader_shapes_dtype_wraparound(self, harvest_dir, step, layer):
        d, meta = harvest_dir
        reader = ActivationReader(d, DataConfig(
            vocab=1, seq_len=0, global_batch=8, microbatch=4,
            activation_dir=str(d), activation_layer=layer))
        b = reader.batch(step)
        assert b.shape == (8, meta["d_model"])
        assert str(b.dtype) == meta["dtype"]
        # stateless cursor: same step -> identical rows; wrap-around is modular
        np.testing.assert_array_equal(b, reader.batch(step))
        n_rows = meta["rows_per_shard"] * meta["n_shards"]
        np.testing.assert_array_equal(
            b, reader.batch(step + n_rows // 8))

    def test_pipeline_microbatch_layout(self, harvest_dir):
        d, meta = harvest_dir
        pipe = DataPipeline(DataConfig(
            vocab=1, seq_len=0, global_batch=8, microbatch=4,
            activation_dir=str(d), activation_layer=0))
        b = pipe.batch(0)
        assert b.shape == (2, 4, meta["d_model"])
        flat = np.asarray(b).reshape(8, meta["d_model"])
        raw = ActivationReader(d, DataConfig(
            vocab=1, seq_len=0, global_batch=8, microbatch=4,
            activation_dir=str(d), activation_layer=0)).batch(0)
        np.testing.assert_array_equal(flat, raw)

    def test_layer_selection_distinct(self, harvest_dir):
        d, _ = harvest_dir
        def rows(layer):
            return ActivationReader(d, DataConfig(
                vocab=1, seq_len=0, global_batch=8, microbatch=4,
                activation_dir=str(d), activation_layer=layer)).batch(0)
        assert float(np.abs(rows(0) - rows(2)).max()) > 1e-6

    def test_mlp_site_differs_from_resid(self, tmp_path):
        import dataclasses
        fcfg = dataclasses.replace(FCFG, site="mlp", layers=(0,),
                                   harvest_steps=1)
        meta = F.harvest_activations(fcfg, tmp_path)
        assert meta["site"] == "mlp"
        assert meta["d_model"] > 0


# ------------------------------------------------------------- MMCS invariants
class TestMMCSInvariants:
    @given(d=st.integers(4, 24), k=st.integers(2, 24),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_self_similarity_is_one(self, d, k, seed):
        a = _rand((d, k), seed, scale=2.0)
        assert float(mmcs(a, a)) == pytest.approx(1.0, abs=1e-5)

    @given(d=st.integers(4, 24), k=st.integers(2, 24),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_permutation_and_sign_invariance(self, d, k, seed):
        a = _rand((d, k), seed, scale=2.0)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(k)
        signs = jnp.asarray(rng.choice([-1.0, 1.0], size=k), jnp.float32)
        b = a[:, perm] * signs[perm]
        assert float(mmcs(a, b)) == pytest.approx(1.0, abs=1e-5)
        assert float(mmcs_sym(a, b)) == pytest.approx(1.0, abs=1e-5)

    @given(d=st.integers(4, 16), k1=st.integers(2, 16), k2=st.integers(2, 16),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_symmetry_and_range(self, d, k1, k2, seed):
        a = _rand((d, k1), seed, scale=2.0)
        b = _rand((d, k2), seed + 1, scale=2.0)
        s1, s2 = float(mmcs_sym(a, b)), float(mmcs_sym(b, a))
        assert s1 == pytest.approx(s2, abs=1e-6)
        assert 0.0 <= s1 <= 1.0 + 1e-6
        assert 0.0 <= float(mmcs(a, b)) <= 1.0 + 1e-6

    def test_table(self):
        dicts = {"a": _rand((8, 6), 0), "b": _rand((8, 5), 1),
                 "c": _rand((8, 6), 2)}
        t = mmcs_table(dicts)
        assert set(t) == {("a", "b"), ("a", "c"), ("b", "c")}
        assert t[("a", "b")] == pytest.approx(
            float(mmcs_sym(dicts["a"], dicts["b"])), abs=1e-6)
        assert all(0.0 <= v <= 1.0 + 1e-6 for v in t.values())


# ----------------------------------------------- §7.3 tables tiny regression
# pinned on the seed container (seed=0, 160×96, 25 epochs); tolerances cover
# BLAS-level drift, structure assertions catch method regressions outright
_PINNED = {
    "baseline":      (84.4, 0.0),
    "exact_l1inf":   (84.4, 2.1),
    "bilevel_l1inf": (56.2, 65.6),
    "bilevel_l11":   (81.2, 2.1),
    "bilevel_l12":   (81.2, 6.2),
}


@pytest.mark.slow
def test_sae_tables_tiny_regression_slow():
    # the nightly (-m "") run covers the committed bench config itself
    from benchmarks.sae_tables import tables
    rows = tables(full=False)
    assert len(rows) == 10


def test_sae_tables_tiny_regression():
    from benchmarks.sae_tables import run_dataset
    from repro.data import classification_synthetic

    x, y, _ = classification_synthetic(n_samples=160, n_features=96,
                                       n_informative=32, class_sep=0.8)
    rows = run_dataset("tiny", x, y, radius=1.0, epochs=25, seed=0)
    got = {}
    for name, _, derived in rows:
        m = re.match(r"sae_tiny_(\w+)", name)
        acc, sp = re.match(r"acc=([\d.]+)%_colsparsity=([\d.]+)%",
                           derived).groups()
        got[m.group(1)] = (float(acc), float(sp))
    assert set(got) == set(_PINNED)
    for method, (acc, sp) in _PINNED.items():
        gacc, gsp = got[method]
        assert gacc == pytest.approx(acc, abs=6.5), method
        assert gsp == pytest.approx(sp, abs=10.0), method
    # structure: only the projected methods sparsify; bi-level ℓ1,∞ dominates
    assert got["baseline"][1] == 0.0
    assert got["bilevel_l1inf"][1] > 40.0


def test_double_descent_no_rewind_ablation():
    from benchmarks.sae_tables import run_dataset
    from repro.data import classification_synthetic

    x, y, _ = classification_synthetic(n_samples=120, n_features=64,
                                       n_informative=16, class_sep=0.8)
    rows = run_dataset("nr", x, y, radius=1.0, epochs=10, seed=0,
                       rewind=False, only=("bilevel_l1inf",))
    assert len(rows) == 1
    sp = float(re.search(r"colsparsity=([\d.]+)%", rows[0][2]).group(1))
    assert sp > 10.0   # the mask (not the rewind) carries the sparsity


# ------------------------------------------------------- end-to-end factory
def test_factory_end_to_end(harvest_dir):
    d, meta = harvest_dir
    run = F.train_sae(d, 0, FCFG, seed=0)
    dm = meta["d_model"]
    assert run["dictionary"].shape == (dm, FCFG.expansion * dm)
    assert np.isfinite(run["metrics"]["loss"])
    # the per-step constraint holds on the FINAL params (projection is the
    # last thing the fused epilogue does)
    rep = F.constraint_report(run["params"], F.sae_projection_spec(FCFG))
    assert rep["feasible"], rep
    # cross-seed MMCS is a proper similarity
    run2 = F.train_sae(d, 0, FCFG, seed=1)
    s = float(mmcs_sym(run["dictionary"], run2["dictionary"]))
    assert 0.0 < s <= 1.0
    # determinism: same seed, same dictionary
    again = F.train_sae(d, 0, FCFG, seed=0)
    np.testing.assert_allclose(run["dictionary"], again["dictionary"],
                               atol=1e-6)


def test_factory_head_structured_tri_level(harvest_dir):
    # heads>1: 3-D encoder (d_in, heads, d//heads), tri-level l1,inf,inf ball
    import dataclasses
    d, meta = harvest_dir
    hcfg = dataclasses.replace(FCFG, heads=2)
    assert F.effective_levels(hcfg) == (("inf", 1),) + tuple(FCFG.levels)
    assert F.sae_projection_spec(hcfg).levels == F.effective_levels(hcfg)
    # an explicit 3-axis design wins over the implicit upgrade
    explicit = dataclasses.replace(
        hcfg, levels=(("2", 1), ("inf", 1), ("1", 1)))
    assert F.effective_levels(explicit) == explicit.levels
    run = F.train_sae(d, 0, hcfg, seed=0)
    dm = meta["d_model"]
    assert run["params"]["enc"]["w"].shape == (dm, 2, hcfg.expansion * dm // 2)
    # the dictionary flattens the head axes back for MMCS
    assert run["dictionary"].shape == (dm, hcfg.expansion * dm)
    rep = F.constraint_report(run["params"], F.sae_projection_spec(hcfg))
    assert rep["feasible"], rep
    assert np.isfinite(run["metrics"]["mse"])


def test_dict_template_head_validation():
    from repro.models import sae
    with pytest.raises(ValueError, match="divisible"):
        sae.dict_template(8, 30, heads=4)
    tpl = sae.dict_template(8, 32, heads=4)
    assert tpl["enc"]["w"].shape == (8, 4, 8)
    assert tpl["dec"]["w"].shape == (4, 8, 8)


def test_head_structured_forward_matches_flat_math():
    # flattening the head axes reproduces the 2-D matmul exactly
    import jax
    from repro.models import params as PM, sae
    key = jax.random.PRNGKey(0)
    p3 = PM.init_params(sae.dict_template(8, 16, heads=4), key)
    p2 = jax.tree_util.tree_map(np.asarray, p3)
    p2["enc"]["w"] = p2["enc"]["w"].reshape(8, 16)
    p2["dec"]["w"] = p2["dec"]["w"].reshape(16, 8)
    x = _rand((6, 8), seed=5)
    f3, r3 = sae.dict_forward(p3, x)
    f2, r2 = sae.dict_forward({k: {kk: jnp.asarray(v) for kk, v in d.items()}
                               for k, d in p2.items()}, x)
    np.testing.assert_allclose(np.asarray(f3), np.asarray(f2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(r3), np.asarray(r2), atol=1e-6)


def test_run_factory_accepts_checkpoint_params(tmp_path):
    # --checkpoint path: harvest from explicit LM weights, not the seeded init
    import dataclasses
    import jax
    fcfg = dataclasses.replace(FCFG, layers=(0,), harvest_steps=1,
                               train_steps=2)
    _, _, params = F.lm_for(fcfg)
    scaled = jax.tree_util.tree_map(lambda w: w * 1.5, params)
    out = F.run_factory(fcfg, tmp_path, seeds=(0,), lm_params=scaled)
    assert 0 in out["layers"]
    # different weights -> different activations than the default harvest
    d2 = tmp_path / "default"
    d2.mkdir()
    F.harvest_activations(fcfg, d2)
    a = np.load(next(tmp_path.glob("layer*_shard*.npy")))
    b = np.load(next(d2.glob("layer*_shard*.npy")))
    assert float(np.abs(a - b).max()) > 1e-6


def test_gsp_whole_network_single_device():
    g = F.gsp_whole_network(steps=1)
    assert g["n_projected"] >= 10       # every ≥2-D weight of the smoke LM
    assert g["feasible"], g
    assert np.isfinite(g["loss"])


_GSP_CHILD = """
import json, jax
from repro.launch.mesh import make_host_mesh
from repro.training import sae_factory as F
assert jax.device_count() == 8, jax.device_count()
g = F.gsp_whole_network(mesh=make_host_mesh(1, 8), steps=2)
print("RESULT" + json.dumps({k: v for k, v in g.items()
                             if k != "per_leaf_sparsity"}))
"""


def test_gsp_whole_network_8dev_mesh_executor():
    """Whole-network GSP through the §3 mesh executor on a forced 8-device
    CPU mesh (subprocess: device count is fixed at interpreter start)."""
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    res = subprocess.run([sys.executable, "-c", _GSP_CHILD],
                         capture_output=True, text=True, timeout=900, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    g = json.loads(res.stdout.split("RESULT", 1)[1])
    assert g["n_devices"] == 8
    assert g["n_projected"] >= 10
    assert g["feasible"], g
    # sharded and single-device paths optimize the same function
    ref = F.gsp_whole_network(steps=2)
    assert g["loss"] == pytest.approx(ref["loss"], rel=1e-3)
