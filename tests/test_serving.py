"""Serving engine: greedy generation, batched requests, ring caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.models import params as PM
from repro.serving import engine


def _setup(name, seed=0):
    cfg = registry.smoke_config(name)
    api = models.get(cfg)
    params = PM.init_params(api.template(cfg), jax.random.PRNGKey(seed))
    return cfg, api, params


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg, api, params = _setup("granite-3-2b")
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)), jnp.int32)
        a = engine.generate(params, cfg, prompt, max_new=6)
        b = engine.generate(params, cfg, prompt, max_new=6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (2, 6)

    def test_batch_independence(self):
        # each request decodes as if alone in the batch
        cfg, api, params = _setup("granite-3-2b")
        rng = np.random.default_rng(1)
        p1 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
        p2 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
        both = jnp.concatenate([p1, p2], axis=0)
        o_both = engine.generate(params, cfg, both, max_new=5)
        o_1 = engine.generate(params, cfg, p1, max_new=5)
        np.testing.assert_array_equal(np.asarray(o_both[0]), np.asarray(o_1[0]))

    def test_swa_ring_cache_generation(self):
        # windowed arch with prompt longer than the ring: must not crash and
        # must agree with teacher-forced forward on the final logits
        cfg, api, params = _setup("h2o-danube-1.8b")
        assert cfg.window == 16
        prompt = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (1, 24)), jnp.int32)
        cache = api.make_cache(cfg, 1, max_len=40, dtype=jnp.float32)
        step = engine.make_decode_step(cfg, api)
        logits = None
        for i in range(prompt.shape[1]):
            _, logits, cache = step(params, prompt[:, i], cache, jnp.int32(i))
        full, _ = api.forward(params, prompt, cfg, impl="naive", remat=False)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                                   rtol=5e-3, atol=5e-3)

    def test_recurrent_arch_generation(self):
        cfg, api, params = _setup("xlstm-1.3b")
        prompt = jnp.asarray(
            np.random.default_rng(3).integers(0, cfg.vocab, (2, 6)), jnp.int32)
        out = engine.generate(params, cfg, prompt, max_new=4)
        assert out.shape == (2, 4)
        assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab))

    def test_prefill_last_logits_match_decode(self):
        cfg, api, params = _setup("granite-3-2b")
        prompt = jnp.asarray(
            np.random.default_rng(4).integers(0, cfg.vocab, (2, 10)), jnp.int32)
        pre = engine.make_prefill(cfg, api, impl="naive")
        last = pre(params, prompt)
        cache = api.make_cache(cfg, 2, max_len=16, dtype=jnp.float32)
        step = engine.make_decode_step(cfg, api)
        logits = None
        for i in range(10):
            _, logits, cache = step(params, prompt[:, i], cache, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(last),
                                   rtol=5e-3, atol=5e-3)
