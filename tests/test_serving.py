"""Serving tier: LM generation, flush()-batched service, and the
continuous-batching ProjectionEngine (typed failures, donation, batching)."""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.models import params as PM
from repro.serving import lm


def _setup(name, seed=0):
    cfg = registry.smoke_config(name)
    api = models.get(cfg)
    params = PM.init_params(api.template(cfg), jax.random.PRNGKey(seed))
    return cfg, api, params


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg, api, params = _setup("granite-3-2b")
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)), jnp.int32)
        a = lm.generate(params, cfg, prompt, max_new=6)
        b = lm.generate(params, cfg, prompt, max_new=6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (2, 6)

    def test_batch_independence(self):
        # each request decodes as if alone in the batch
        cfg, api, params = _setup("granite-3-2b")
        rng = np.random.default_rng(1)
        p1 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
        p2 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
        both = jnp.concatenate([p1, p2], axis=0)
        o_both = lm.generate(params, cfg, both, max_new=5)
        o_1 = lm.generate(params, cfg, p1, max_new=5)
        np.testing.assert_array_equal(np.asarray(o_both[0]), np.asarray(o_1[0]))

    def test_swa_ring_cache_generation(self):
        # windowed arch with prompt longer than the ring: must not crash and
        # must agree with teacher-forced forward on the final logits
        cfg, api, params = _setup("h2o-danube-1.8b")
        assert cfg.window == 16
        prompt = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (1, 24)), jnp.int32)
        cache = api.make_cache(cfg, 1, max_len=40, dtype=jnp.float32)
        step = lm.make_decode_step(cfg, api)
        logits = None
        for i in range(prompt.shape[1]):
            _, logits, cache = step(params, prompt[:, i], cache, jnp.int32(i))
        full, _ = api.forward(params, prompt, cfg, impl="naive", remat=False)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                                   rtol=5e-3, atol=5e-3)

    def test_recurrent_arch_generation(self):
        cfg, api, params = _setup("xlstm-1.3b")
        prompt = jnp.asarray(
            np.random.default_rng(3).integers(0, cfg.vocab, (2, 6)), jnp.int32)
        out = lm.generate(params, cfg, prompt, max_new=4)
        assert out.shape == (2, 4)
        assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab))

    def test_prefill_last_logits_match_decode(self):
        cfg, api, params = _setup("granite-3-2b")
        prompt = jnp.asarray(
            np.random.default_rng(4).integers(0, cfg.vocab, (2, 10)), jnp.int32)
        pre = lm.make_prefill(cfg, api, impl="naive")
        last = pre(params, prompt)
        cache = api.make_cache(cfg, 2, max_len=16, dtype=jnp.float32)
        step = lm.make_decode_step(cfg, api)
        logits = None
        for i in range(10):
            _, logits, cache = step(params, prompt[:, i], cache, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(last),
                                   rtol=5e-3, atol=5e-3)


# ------------------------------------------------------- projection service
class TestProjectionService:
    """Plan-batched heterogeneous projection requests (serving/projection_service)."""

    def _svc(self, method="sort"):
        from repro.core import plan
        from repro.serving import ProjectionService
        plan.clear_cache()
        return ProjectionService(method=method)

    def test_heterogeneous_requests_grouped_by_plan_key(self):
        from repro.core import multilevel
        svc = self._svc()
        rng = np.random.default_rng(0)
        mats = [jnp.asarray(rng.normal(size=(6, 10)), jnp.float32) for _ in range(3)]
        vec = jnp.asarray(rng.normal(size=(40,)), jnp.float32)
        lv2, lv1 = [("inf", 1), ("1", 1)], [("1", 1)]
        tickets = [svc.submit(m, lv2, radius=r) for m, r in zip(mats, (0.5, 1.0, 2.0))]
        tv = svc.submit(vec, lv1, radius=1.0)
        assert svc.pending() == 4
        svc.flush()
        # 3 same-key matrices batched into ONE vmap'd dispatch + 1 singleton
        assert svc.stats["executed_batches"] == 2
        assert svc.stats["batched_requests"] == 3
        assert svc.pending() == 0
        for t, m, r in zip(tickets, mats, (0.5, 1.0, 2.0)):
            want = multilevel.multilevel_project(m, lv2, r, method="sort")
            np.testing.assert_allclose(svc.result(t), want, atol=1e-5)
        from repro.core import ball
        np.testing.assert_allclose(svc.result(tv),
                                   ball.project_l1(vec, 1.0), atol=1e-5)

    def test_results_keyed_by_ticket_not_order(self):
        from repro.core import ball
        svc = self._svc()
        a = jnp.asarray(np.random.default_rng(1).normal(size=(8,)), jnp.float32)
        b = jnp.asarray(np.random.default_rng(2).normal(size=(8,)), jnp.float32)
        ta = svc.submit(a, [("1", 1)], 1.0)
        tb = svc.submit(b, [("1", 1)], 1.0)
        svc.flush()
        np.testing.assert_allclose(svc.result(tb), ball.project_l1(b, 1.0),
                                   atol=1e-6)
        np.testing.assert_allclose(svc.result(ta), ball.project_l1(a, 1.0),
                                   atol=1e-6)

    def test_project_convenience_and_auto(self):
        from repro.core import multilevel
        svc = self._svc(method="auto")
        y = jnp.asarray(np.random.default_rng(3).normal(size=(5, 9)), jnp.float32)
        lv = [("inf", 1), ("1", 1)]
        got = svc.project(y, lv, 1.5)
        want = multilevel.multilevel_project(y, lv, 1.5, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_unflushed_ticket_raises(self):
        svc = self._svc()
        t = svc.submit(jnp.ones((4,)), [("1", 1)], 1.0)
        with pytest.raises(KeyError):
            svc.result(t)  # submitted but never flushed

    def test_bad_request_rejected_at_submit_not_flush(self):
        # an invalid request must fail at submit() — raising inside flush()
        # would abort the whole batch and wedge the queue
        from repro.core import ball
        svc = self._svc()
        good = jnp.asarray(np.random.default_rng(4).normal(size=(4,)), jnp.float32)
        t = svc.submit(good, [("1", 1)], 1.0)
        with pytest.raises(ValueError):  # 2 levels cover 2 axes, tensor has 3
            svc.submit(jnp.ones((4, 6, 2)), [("inf", 1), ("1", 1)], 1.0)
        with pytest.raises(ValueError):  # unknown backend name
            svc.submit(good, [("1", 1)], 1.0, method="nope")
        with pytest.raises(ValueError):  # non-scalar radius
            svc.submit(good, [("1", 1)], jnp.ones((3,)))
        assert svc.pending() == 1
        svc.flush()
        assert svc.pending() == 0
        np.testing.assert_allclose(svc.result(t), ball.project_l1(good, 1.0),
                                   atol=1e-6)

    def test_group_sizes_bucket_to_one_trace(self):
        # group sizes 3 and 4 share the pow-2 bucket -> ONE trace of the
        # batch executable, not one per distinct group size
        from repro.core import plan as planmod
        svc = self._svc()
        rng = np.random.default_rng(6)
        lv = [("1", 1)]
        for size in (3, 4):
            for _ in range(size):
                svc.submit(jnp.asarray(rng.normal(size=(16,)), jnp.float32),
                           lv, 1.0)
            svc.flush()
        p = planmod.make_plan((16,), jnp.float32, lv, radius_kind="batch",
                              method="sort")
        assert p.trace_count == 1

    def test_method_aliases_share_a_batch(self):
        # michelot is an alias of filter: both requests fold to one group
        svc = self._svc(method="filter")
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)
        lv = [("inf", 1), ("1", 1)]
        ta = svc.submit(a, lv, 1.0)
        tb = svc.submit(b, lv, 1.0, method="michelot")
        svc.flush()
        assert svc.stats["executed_batches"] == 1
        assert svc.stats["batched_requests"] == 2
        svc.result(ta), svc.result(tb)


# ------------------------------------------------------- projection engine
class TestProjectionEngine:
    """Continuous-batching async engine (serving/engine): typed failure
    paths, donation invariants, dispatch-join behaviour."""

    def _eng(self, **kw):
        from repro.core import plan
        from repro.serving import ProjectionEngine
        plan.clear_cache()
        kw.setdefault("method", "sort")
        kw.setdefault("start", False)  # deterministic: drain() dispatches
        return ProjectionEngine(**kw)

    def test_pending_requests_join_one_dispatch(self):
        # continuous batching: every request queued for a key joins the
        # next dispatch for that key — one executable call for all five
        from repro.core import multilevel
        eng = self._eng()
        rng = np.random.default_rng(0)
        lv = [("inf", 1), ("1", 1)]
        ys = [jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
              for _ in range(5)]
        wants = [multilevel.multilevel_project(y, lv, 0.5 + 0.25 * i,
                                               method="sort")
                 for i, y in enumerate(ys)]  # before submit: ys get donated
        ts = [eng.submit(y, lv, radius=0.5 + 0.25 * i)
              for i, y in enumerate(ys)]
        eng.drain()
        assert eng.stats["dispatches"] == 1
        assert eng.stats["batched_requests"] == 5
        for t, want in zip(ts, wants):
            np.testing.assert_allclose(eng.result(t), want, atol=1e-5)
        eng.stop()

    def test_threaded_submit_poll_result(self):
        from repro.core import ball
        eng = self._eng(start=True)
        y = jnp.asarray(np.random.default_rng(1).normal(size=(16,)),
                        jnp.float32)
        want = ball.project_l1(y, 1.0)  # before submit: y gets donated
        t = eng.submit(y, [("1", 1)], radius=1.0)
        out = eng.result(t, timeout=60.0)
        assert eng.poll(t)
        np.testing.assert_allclose(out, want, atol=1e-6)
        eng.stop()

    def test_singleton_donates_callers_buffer(self):
        # donation invariant: a singleton dispatch consumes the submitted
        # buffer (in-place projection, no payload copy)
        eng = self._eng(donate=True)
        y = jnp.asarray(np.random.default_rng(2).normal(size=(6, 10)),
                        jnp.float32)
        t = eng.submit(y, [("inf", 1), ("1", 1)], radius=1.0)
        out = eng.result(t)
        assert y.is_deleted()
        assert not out.is_deleted()
        eng.stop()

    def test_donate_false_preserves_buffers(self):
        eng = self._eng(donate=False)
        y = jnp.asarray(np.random.default_rng(3).normal(size=(6, 10)),
                        jnp.float32)
        eng.result(eng.submit(y, [("inf", 1), ("1", 1)], radius=1.0))
        assert not y.is_deleted()
        eng.stop()

    def test_queue_full_typed_rejection(self):
        from repro.serving import QueueFullError, ServingError
        eng = self._eng(max_pending=2)
        eng.submit(jnp.ones((4,)), [("1", 1)])
        eng.submit(jnp.ones((4,)), [("1", 1)])
        with pytest.raises(QueueFullError) as ei:
            eng.submit(jnp.ones((4,)), [("1", 1)])
        assert isinstance(ei.value, ServingError)  # typed, catchable family
        assert eng.stats["rejected"] == 1
        eng.stop()

    def test_deadline_expired_before_dispatch(self):
        from repro.serving import DeadlineExceededError
        eng = self._eng()
        t = eng.submit(jnp.ones((8,)), [("1", 1)], deadline=0.0)
        time.sleep(0.01)
        eng.drain()
        assert eng.stats["expired"] == 1
        with pytest.raises(DeadlineExceededError):
            eng.result(t)
        eng.stop()

    def test_failed_group_requeues_then_fails_typed(self):
        # a dispatch that raises re-queues its group; after max_attempts
        # the tickets complete exceptionally with the stored error
        from repro.serving import ServingError
        eng = self._eng(max_attempts=2)
        calls = []

        def flaky(key, plans, live):
            calls.append(len(live))
            raise RuntimeError("injected dispatch failure")

        eng._run_group = flaky
        t = eng.submit(jnp.ones((8,)), [("1", 1)])
        eng.drain()
        assert calls == [1, 1]  # original attempt + one re-queue
        assert eng.stats["requeues"] == 1 and eng.stats["failures"] == 1
        with pytest.raises(ServingError, match="injected"):
            eng.result(t)
        eng.stop()

    def test_unknown_and_discarded_ticket_raise_typed(self):
        from repro.serving import UnknownTicketError
        eng = self._eng()
        with pytest.raises(UnknownTicketError):
            eng.result(object())  # foreign handle
        t = eng.submit(jnp.ones((8,)), [("1", 1)])
        eng.discard(t)
        eng.drain()
        with pytest.raises(UnknownTicketError):
            eng.result(t)
        t2 = eng.submit(jnp.ones((8,)), [("1", 1)])
        eng.result(t2)
        with pytest.raises(UnknownTicketError):
            eng.result(t2)  # single read: second claim is unknown
        eng.stop()

    def test_batch_native_backend_routes_singleton_via_batch_plan(self):
        # codegen_batch executables take stacked buckets only: a size-1
        # group must still dispatch through the batch plan, and the
        # answer must match the reference
        from repro.core import multilevel
        eng = self._eng(method="codegen_batch", interpret=True)
        y = jnp.asarray(np.random.default_rng(5).normal(size=(6, 10)),
                        jnp.float32)
        lv = [("inf", 1), ("1", 1)]
        want = multilevel.multilevel_project(y, lv, 0.7, method="sort")
        out = eng.project(y, lv, radius=0.7)
        np.testing.assert_allclose(out, want, atol=1e-5)
        eng.stop()

    def test_bad_request_rejected_at_submit(self):
        eng = self._eng()
        with pytest.raises(ValueError):
            eng.submit(jnp.ones((4, 6, 2)), [("inf", 1), ("1", 1)])
        with pytest.raises(ValueError):
            eng.submit(jnp.ones((4,)), [("1", 1)], method="nope")
        with pytest.raises(ValueError):
            eng.submit(jnp.ones((4,)), [("1", 1)], jnp.ones((3,)))
        assert eng.pending() == 0
        eng.stop()

    def test_stop_then_submit_raises(self):
        from repro.serving import ServingError
        eng = self._eng()
        eng.stop()
        with pytest.raises(ServingError):
            eng.submit(jnp.ones((4,)), [("1", 1)])

    def test_context_manager_drains(self):
        from repro.core import ball
        from repro.serving import ProjectionEngine
        y = jnp.asarray(np.random.default_rng(6).normal(size=(16,)),
                        jnp.float32)
        want = ball.project_l1(y, 1.0)  # before submit: y gets donated
        with ProjectionEngine(method="sort") as eng:
            t = eng.submit(y, [("1", 1)], radius=1.0)
            out = eng.result(t, timeout=60.0)
        np.testing.assert_allclose(out, want, atol=1e-6)


class TestEngineObservability:
    """PR-10 serving telemetry: the stats() snapshot and its accounting
    invariant, the single monotonic clock behind every deadline, and the
    instrument=False bare path."""

    def _eng(self, **kw):
        from repro.core import plan
        from repro.serving import ProjectionEngine
        plan.clear_cache()
        kw.setdefault("method", "sort")
        kw.setdefault("start", False)
        return ProjectionEngine(**kw)

    @staticmethod
    def _accounted(s):
        return (s["completed"] + s["failed"] + s["discarded"]
                + s["queued"] + s["inflight"])

    def test_stats_dict_and_callable(self):
        # back-compat: eng.stats is the counters dict; eng.stats() is the
        # structured snapshot
        eng = self._eng()
        eng.result(eng.submit(jnp.ones((8,)), [("1", 1)]))
        assert eng.stats["dispatches"] == 1
        snap = eng.stats()
        assert snap["dispatches"] == 1 and snap["queued"] == 0
        eng.stop()

    def test_lifecycle_invariant(self):
        # pinned by stats_snapshot's docstring:
        #   completed + failed + discarded + queued + inflight == submitted
        eng = self._eng()
        lv = [("1", 1)]
        ts = [eng.submit(jnp.ones((8,)), lv) for _ in range(5)]
        s = eng.stats()
        assert s["submitted"] == 5 and s["queued"] == 5
        assert self._accounted(s) == 5
        eng.discard(ts[0])
        s = eng.stats()
        assert s["discarded"] == 1 and self._accounted(s) == 5
        eng.drain()
        s = eng.stats()
        assert s["completed"] == 4 and self._accounted(s) == 5
        # failed leg: every dispatch attempt raises -> tickets end failed
        def boom(key, plans, live):
            raise RuntimeError("injected")
        eng._run_group = boom
        eng.submit(jnp.ones((8,)), lv)
        eng.drain()
        s = eng.stats()
        assert s["failed"] == 1 and self._accounted(s) == s["submitted"] == 6
        eng.stop()

    def test_rejected_not_counted_as_submitted(self):
        from repro.serving import QueueFullError
        eng = self._eng(max_pending=1)
        eng.submit(jnp.ones((8,)), [("1", 1)])
        with pytest.raises(QueueFullError):
            eng.submit(jnp.ones((8,)), [("1", 1)])
        s = eng.stats()
        assert s["rejected"] == 1 and s["submitted"] == 1
        assert self._accounted(s) == 1
        eng.stop()

    def test_snapshot_latency_and_plan_cache(self):
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.Registry()
        prev = obs_metrics.set_registry(reg)
        try:
            eng = self._eng()
            for i in range(3):
                eng.result(eng.submit(
                    jnp.full((6, 10), float(i + 1)),
                    [("inf", 1), ("1", 1)], radius=1.0))
            snap = eng.stats()
            assert snap["latency"], "instrumented engine reports latency"
            (key, lat), = snap["latency"].items()
            assert "6x10" in key and lat["e2e_count"] == 3
            assert lat["e2e_p99_s"] >= lat["e2e_p50_s"] >= 0.0
            # bucket-interpolated: all-singleton batches estimate inside
            # the (0, 1] bucket
            assert 0.0 < snap["batch_p50"] <= 1.0
            assert snap["plan_cache"]["plans"] >= 1
            # the same series back the Prometheus export
            text = reg.to_prometheus()
            assert "serving_e2e_seconds_bucket" in text
            assert 'serving_events_total{event="completed"} 3' in text
            eng.stop()
        finally:
            obs_metrics.set_registry(prev)

    def test_instrument_false_bare_path(self):
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.Registry()
        prev = obs_metrics.set_registry(reg)
        try:
            eng = self._eng(instrument=False)
            eng.result(eng.submit(jnp.ones((8,)), [("1", 1)]))
            snap = eng.stats()
            assert snap["completed"] == 1
            assert "latency" not in snap and "batch_p50" not in snap
            assert self._accounted(snap) == 1
            # nothing was recorded into the registry by this engine
            assert not any(n.startswith("serving_")
                           for n in reg.snapshot())
            eng.stop()
        finally:
            obs_metrics.set_registry(prev)

    def test_engine_source_never_reads_wall_clock(self):
        # the single-clock satellite: every engine timestamp goes through
        # the module-level ``_now`` (monotonic); wall clock is forbidden
        import inspect

        from repro.serving import engine as engmod
        src = inspect.getsource(engmod)
        assert "time.time(" not in src
        assert engmod._now is time.monotonic

    def test_wall_clock_jump_does_not_expire_deadlines(self, monkeypatch):
        # regression: an NTP step / wall-clock jump mid-flight must not
        # expire deadlines — they live on the fake-able monotonic ``_now``
        from repro.serving import DeadlineExceededError
        from repro.serving import engine as engmod
        fake = {"t": 1000.0}
        monkeypatch.setattr(engmod, "_now", lambda: fake["t"])
        eng = self._eng()
        t1 = eng.submit(jnp.ones((8,)), [("1", 1)], deadline=5.0)
        with monkeypatch.context() as mp:
            # wall clock leaps a year; monotonic advanced only 1s
            mp.setattr(time, "time", lambda: time.monotonic() + 3.2e7)
            fake["t"] += 1.0
            eng.drain()
        assert jnp.asarray(eng.result(t1)).shape == (8,)
        assert eng.stats["expired"] == 0
        # the monotonic clock alone drives expiry
        t2 = eng.submit(jnp.ones((8,)), [("1", 1)], deadline=5.0)
        fake["t"] += 10.0
        eng.drain()
        assert eng.stats["expired"] == 1
        with pytest.raises(DeadlineExceededError):
            eng.result(t2)
        eng.stop()
