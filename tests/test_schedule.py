"""The schedule IR (core.schedule): compilation structure, executor equality
with the recursion it replaced, batch dims, and the collective-bytes model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ball, multilevel
from repro.core import schedule as SC

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]


def _rand(shape, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _reference_recursion(y, levels, radius, method="sort"):
    """The pre-schedule Algorithm 6 recursion, kept as the oracle."""
    (q, k), rest = levels[0], levels[1:]
    if not rest:
        flat = y.reshape(-1)
        return ball.project_ball(flat, q, radius, method=method).reshape(y.shape)
    inner = tuple(range(k))
    v = ball.norm_reduce(y, q, axes=inner)
    u = _reference_recursion(v, rest, radius, method)
    return ball.project_grouped(y, q, u, inner_axes=inner, method=method)


class TestCompile:
    def test_step_structure_trilevel(self):
        s = SC.compile_schedule((4, 8, 16), TRILEVEL)
        kinds = [type(st).__name__ for st in s.steps]
        assert kinds == ["ReduceLevel", "ReduceLevel", "OuterSolve",
                         "ApplyGroup", "ApplyGroup"]
        assert s.reduces[0].axes == (0,) and s.reduces[1].axes == (0,)
        assert s.stage_shapes == ((4, 8, 16), (8, 16), (16,))
        assert s.solve.norm == "1" and s.solve_size == 16
        # applies mirror the reduces, outermost level first
        assert [a.norm for a in s.applies] == ["inf", "inf"]

    def test_single_level_flattens(self):
        s = SC.compile_schedule((4, 8), [("1", 2)])
        assert s.reduces == () and s.applies == ()
        assert s.solve_size == 32

    def test_batch_dims_offset_axes(self):
        s = SC.compile_schedule((3, 4, 8, 16), TRILEVEL, batch_dims=1)
        assert s.reduces[0].axes == (1,) and s.reduces[1].axes == (1,)
        assert s.stage_shapes[-1] == (3, 16)
        assert s.solve_size == 16  # per batch element

    def test_compile_is_cached(self):
        a = SC.compile_schedule((4, 8), BILEVEL)
        b = SC.compile_schedule((4, 8), [(jnp.inf, 1), (1, 1)])
        assert a is b  # canonicalization folds to the same cached object

    def test_validation(self):
        with pytest.raises(ValueError, match="covers"):
            SC.compile_schedule((4, 8, 2), BILEVEL)
        with pytest.raises(ValueError, match="covers"):
            SC.compile_schedule((4, 8), BILEVEL, batch_dims=1)
        with pytest.raises(ValueError, match="at least one axis"):
            SC.compile_schedule((4, 8), [("inf", 0), ("1", 2)])


class TestExecute:
    @pytest.mark.parametrize("shape,levels", [
        ((6, 10), BILEVEL),
        ((3, 6, 10), TRILEVEL),
        ((4, 5), [("2", 1), ("1", 1)]),
        ((4, 5), [("1", 1), ("1", 1)]),
        ((3, 4, 5), [("2", 1), ("1", 2)]),
        ((4, 8), [("1", 2)]),
        ((3, 4, 5), [("1", 1), ("2", 1), ("inf", 1)]),
    ])
    @pytest.mark.parametrize("method", ["sort", "filter"])
    def test_matches_reference_recursion(self, shape, levels, method):
        y = _rand(shape, seed=abs(hash((shape, method))) % 2**31)
        sched = SC.compile_schedule(shape, levels)
        got = SC.execute(y, sched, 1.5, method=method)
        want = _reference_recursion(y, SC.canonical_levels(levels), 1.5, method)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_multilevel_project_runs_the_schedule(self):
        y = _rand((3, 6, 10), seed=3)
        got = multilevel.multilevel_project(y, TRILEVEL, 1.0)
        want = SC.execute(y, SC.compile_schedule(y.shape, TRILEVEL), 1.0)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_batch_dims_equal_vmap(self):
        y = _rand((4, 6, 10), seed=4)
        sched = SC.compile_schedule(y.shape, BILEVEL, batch_dims=1)
        got = SC.execute(y, sched, 1.2)
        want = jax.vmap(lambda w: multilevel.multilevel_project(
            w, BILEVEL, 1.2))(y)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_feasible_after_execute(self):
        y = _rand((5, 7), seed=5)
        sched = SC.compile_schedule(y.shape, BILEVEL)
        out = SC.execute(y, sched, 2.0)
        assert float(multilevel.multilevel_norm(out, BILEVEL)) <= 2.0 * (1 + 1e-5)


class TestCollectiveBytes:
    def test_bilevel_ratio_is_aggregated_extent(self):
        n, m = 1000, 10000
        cb = SC.sharded_collective_bytes((n, m), BILEVEL, (None, "model"),
                                         {"model": 8})
        assert cb["schedule_bytes"] == m * 4       # the gathered aggregate
        assert cb["gather_bytes"] == n * m * 4
        assert cb["ratio"] == pytest.approx(n)

    def test_reduced_sharded_axis_needs_no_gather(self):
        # sharded axis is aggregated at level 0 -> combine payload is the
        # aggregate; the outer solve is already replicated (payload 0)
        cb = SC.sharded_collective_bytes((1000, 64), [("2", 1), ("1", 1)],
                                         ("model", None), {"model": 8})
        steps = {s["step"]: s["bytes"] for s in cb["per_step"]}
        assert steps["reduce_2"] == 64 * 4
        assert steps["solve_1"] == 0
        assert steps["apply_2"] == 0

    def test_distributed_l1_apply_counts_sweeps(self):
        cb = SC.sharded_collective_bytes((128, 64), [("1", 1), ("1", 1)],
                                         ("model", None), {"model": 8})
        steps = {s["step"]: s["bytes"] for s in cb["per_step"]}
        assert steps["apply_1"] == 64 * 4 * SC._L1_APPLY_SWEEPS

    def test_unsharded_design_moves_nothing(self):
        cb = SC.sharded_collective_bytes((64, 64), BILEVEL, (None, None),
                                         {"model": 8})
        assert cb["schedule_bytes"] == 0
