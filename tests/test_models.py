"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness asserts) and algebraic consistency tests:
prefill-vs-decode equivalence, chunked-vs-naive attention, chunkwise-vs-
sequential mLSTM, chunked-vs-single-step Mamba2."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.models import layers as L
from repro.models import params as PM
from repro.models import xlstm as XL


def _toks(b, s, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32)


# ------------------------------------------------------------------ all archs
@pytest.mark.parametrize("name", registry.ASSIGNED)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, name):
        cfg = registry.smoke_config(name)
        api = models.get(cfg)
        p = PM.init_params(api.template(cfg), jax.random.PRNGKey(0))
        toks = _toks(2, 16, cfg.vocab)
        kw = {"remat": False}
        if cfg.family not in ("ssm", "hybrid"):
            kw["impl"] = "naive"
        logits, aux = api.forward(p, toks, cfg, **kw)
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_one_train_step_no_nans(self, name):
        cfg = registry.smoke_config(name)
        api = models.get(cfg)
        p = PM.init_params(api.template(cfg), jax.random.PRNGKey(1))
        toks = _toks(2, 16, cfg.vocab, seed=1)

        def loss(p):
            kw = {"remat": False}
            if cfg.family not in ("ssm", "hybrid"):
                kw["impl"] = "naive"
            logits, aux = api.forward(p, toks[:, :-1], cfg, **kw)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            tgt = toks[:, 1:]
            nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)
            return jnp.mean(nll) + 0.01 * (aux if isinstance(aux, jax.Array) else 0.0)

        l, g = jax.value_and_grad(loss)(p)
        assert bool(jnp.isfinite(l))
        leaves = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)
        # loss near log(vocab) at init
        assert float(l) < np.log(cfg.vocab) * 2 + 1


# --------------------------------------------------------- decode == prefill
@pytest.mark.parametrize(
    "name", ["granite-3-2b", "h2o-danube-1.8b", "qwen3-32b", "deepseek-v3-671b"])
def test_lm_decode_matches_forward(name):
    """Greedy decode logits at each position == teacher-forced forward logits.

    MoE archs use a drop-free capacity factor here: capacity-based token
    dropping legitimately differs between teacher-forced prefill and
    token-by-token decode (documented MoE property, not a bug)."""
    import dataclasses
    cfg = registry.smoke_config(name)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = models.get(cfg)
    p = PM.init_params(api.template(cfg), jax.random.PRNGKey(2))
    b, s = 2, 12
    toks = _toks(b, s, cfg.vocab, seed=2)
    full, _ = api.forward(p, toks, cfg, impl="naive", remat=False)
    cache = api.make_cache(cfg, b, max_len=32, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = api.decode_step(p, toks[:, i], cache, i, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_xlstm_decode_matches_forward():
    cfg = registry.smoke_config("xlstm-1.3b")
    api = models.get(cfg)
    p = PM.init_params(api.template(cfg), jax.random.PRNGKey(3))
    b, s = 2, 10
    toks = _toks(b, s, cfg.vocab, seed=3)
    full, _ = api.forward(p, toks, cfg, seq_mode="sequential", remat=False)
    state = XL.make_state(cfg, b)
    outs = []
    for i in range(s):
        logits, state = api.decode_step(p, toks[:, i], state, i, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_zamba_decode_matches_forward():
    cfg = registry.smoke_config("zamba2-7b")
    api = models.get(cfg)
    p = PM.init_params(api.template(cfg), jax.random.PRNGKey(4))
    b, s = 2, 10
    toks = _toks(b, s, cfg.vocab, seed=4)
    full, _ = api.forward(p, toks, cfg, remat=False)
    cache = api.make_cache(cfg, b, max_len=16, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = api.decode_step(p, toks[:, i], cache, i, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_whisper_decode_matches_forward():
    cfg = registry.smoke_config("whisper-large-v3")
    api = models.get(cfg)
    from repro.models import whisper as W
    p = PM.init_params(api.template(cfg), jax.random.PRNGKey(5))
    b, s = 2, 8
    toks = _toks(b, s, cfg.vocab, seed=5)
    frames = jnp.asarray(np.random.default_rng(6).normal(
        size=(b, cfg.enc_frames, cfg.d_model)) * 0.1, jnp.float32)
    full, _ = api.forward(p, toks, cfg, frames=frames, impl="naive", remat=False)
    enc = W.encode(p, frames, cfg, impl="naive", remat=False)
    cache = api.make_cache(cfg, b, max_len=16, dtype=jnp.float32)
    # populate cross K/V from encoder states
    xk = jnp.einsum("bsd,ldhk->lbshk", enc, p["dec_blocks"]["cross"]["wk"])
    xv = jnp.einsum("bsd,ldhk->lbshk", enc, p["dec_blocks"]["cross"]["wv"]) \
        + p["dec_blocks"]["cross"]["bv"][:, None, None]
    cache = dict(cache, xk=xk, xv=xv)
    outs = []
    for i in range(s):
        logits, cache = api.decode_step(p, toks[:, i], cache, i, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


# -------------------------------------------------- attention impl equivalence
class TestAttentionImpls:
    @pytest.mark.parametrize("window", [None, 8])
    @pytest.mark.parametrize("s,t", [(16, 16), (7, 33)])
    def test_chunked_matches_naive(self, window, s, t):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, s, 8, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, t, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, t, 4, 16)), jnp.float32)
        off = t - s
        a = L.attention_naive(q, k, v, causal=True, window=window, q_offset=off)
        b = L.attention_chunked(q, k, v, causal=True, window=window,
                                q_offset=off, chunk=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_decode_matches_naive_last_row(self):
        rng = np.random.default_rng(1)
        b, t, h, kv, d = 2, 24, 8, 4, 16
        q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, t, kv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, t, kv, d)), jnp.float32)
        full = L.attention_naive(q, k, v, causal=True, q_offset=t - 1)
        dec = L.attention_decode(q[:, 0], k, v, jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 0]),
                                   atol=1e-5)


# ---------------------------------------------------------------- mLSTM/mamba
class TestRecurrences:
    def test_mlstm_chunkwise_matches_sequential(self):
        rng = np.random.default_rng(2)
        b, s, h, d = 2, 37, 3, 8
        q, k, v = (jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
                   for _ in range(3))
        li = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)
        lf = jnp.asarray(rng.normal(size=(b, s, h)) - 1.0, jnp.float32)
        lf = -jax.nn.softplus(-lf)
        y1, st1 = XL.mlstm_sequential(q, k, v, li, lf)
        y2, st2 = XL.mlstm_chunkwise(q, k, v, li, lf, chunk=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        for a, b_ in zip(st1, st2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-3, atol=2e-3)

    def test_mamba2_chunked_matches_stepwise(self):
        from repro.configs.types import SSMConfig
        cfg = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk=8,
                        n_groups=2)
        d = 32
        tpl = L.mamba2_template(d, cfg)
        p = PM.init_params(tpl, jax.random.PRNGKey(7))
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2, 21, d)) * 0.5, jnp.float32)
        y_full, _ = L.mamba2_apply(p, x, cfg)
        # stepwise with state
        di = cfg.expand * d
        gn = cfg.n_groups * cfg.d_state
        h = di // cfg.head_dim
        conv0 = jnp.zeros((2, cfg.d_conv, di + 2 * gn), jnp.float32)
        ssm0 = jnp.zeros((2, h, cfg.d_state, cfg.head_dim), jnp.float32)
        state = (conv0, ssm0)
        outs = []
        for i in range(x.shape[1]):
            y, state = L.mamba2_apply(p, x[:, i:i + 1], cfg, state=state)
            outs.append(y)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                                   rtol=2e-3, atol=2e-3)

    def test_mamba2_conv_state_warmup(self):
        # the first d_conv-1 steps must agree too (zero left-padding semantics)
        from repro.configs.types import SSMConfig
        cfg = SSMConfig(d_state=4, d_conv=4, expand=2, head_dim=4, chunk=4,
                        n_groups=1)
        tpl = L.mamba2_template(8, cfg)
        p = PM.init_params(tpl, jax.random.PRNGKey(8))
        x = jnp.asarray(np.random.default_rng(4).normal(size=(1, 3, 8)),
                        jnp.float32)
        y_full, _ = L.mamba2_apply(p, x, cfg)
        conv0 = jnp.zeros((1, 4, 2 * 8 + 2 * 4), jnp.float32)
        ssm0 = jnp.zeros((1, 4, 4, 4), jnp.float32)
        state = (conv0, ssm0)
        outs = []
        for i in range(3):
            y, state = L.mamba2_apply(p, x[:, i:i + 1], cfg, state=state)
            outs.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(y_full), rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------------- MoE
class TestMoE:
    def test_moe_routes_and_balances(self):
        from repro.configs.types import MoEConfig
        cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1,
                        d_shared=16, capacity_factor=2.0)
        tpl = L.moe_template(32, cfg)
        p = PM.init_params(tpl, jax.random.PRNGKey(9))
        x = jnp.asarray(np.random.default_rng(5).normal(size=(64, 32)),
                        jnp.float32)
        y, aux = L.moe_apply(p, x, cfg, n_groups=2)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(aux) > 0.5  # load-balance loss ≈ 1 at uniform routing

    def test_moe_scatter_matches_einsum(self):
        import dataclasses
        from repro.configs.types import MoEConfig
        base = MoEConfig(n_experts=4, top_k=2, d_expert=8, n_shared=0,
                         capacity_factor=4.0)  # high capacity -> no drops
        tpl = L.moe_template(16, base)
        p = PM.init_params(tpl, jax.random.PRNGKey(10))
        x = jnp.asarray(np.random.default_rng(6).normal(size=(32, 16)),
                        jnp.float32)
        y1, _ = L.moe_apply(p, x, base, n_groups=1)
        y2, _ = L.moe_apply(p, x, dataclasses.replace(base, dispatch="scatter"),
                            n_groups=1)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------------- SAE
def test_sae_forward_and_loss():
    from repro.models import sae as S
    cfg = registry.get_arch("sae-paper")
    p = PM.init_params(S.template(cfg), jax.random.PRNGKey(11))
    rng = np.random.default_rng(7)
    batch = {"x": jnp.asarray(rng.normal(size=(8, cfg.d_model)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 2, (8,)), jnp.int32)}
    (l, aux), g = jax.value_and_grad(S.loss_fn, has_aux=True)(p, batch, cfg)
    assert bool(jnp.isfinite(l))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(g))
