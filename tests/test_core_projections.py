"""Unit tests for repro.core — the paper's projection operators.

Hypothesis-based property tests live in test_property_projections.py (they
degrade to a skip when hypothesis is not installed; see the ``test`` extra).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core

jax.config.update("jax_enable_x64", False)

METHODS = core.available_methods()  # ("bisect", "filter", "sort")


def _rand(shape, seed=0, scale=1.0, dist="normal"):
    rng = np.random.default_rng(seed)
    if dist == "normal":
        a = rng.normal(size=shape) * scale
    else:
        a = rng.uniform(0.0, scale, size=shape)
    return jnp.asarray(a, jnp.float32)


# ---------------------------------------------------------------- vector balls
class TestVectorProjections:
    @pytest.mark.parametrize("method", METHODS)
    @pytest.mark.parametrize("n", [1, 2, 7, 128, 1000])
    def test_l1_feasible_and_idempotent(self, method, n):
        y = _rand((n,), seed=n)
        x = core.project_l1(y, 1.0, method=method)
        assert float(jnp.sum(jnp.abs(x))) <= 1.0 + 1e-4
        x2 = core.project_l1(x, 1.0, method=method)
        np.testing.assert_allclose(x, x2, atol=2e-6)

    def test_l1_inside_ball_is_identity(self):
        y = _rand((64,), seed=1) * 0.001
        x = core.project_l1(y, 1.0)
        np.testing.assert_allclose(x, y, atol=1e-7)

    @pytest.mark.parametrize("method", [m for m in METHODS if m != "sort"])
    def test_l1_methods_match_sort(self, method):
        for seed in range(5):
            y = _rand((257,), seed=seed, scale=3.0)
            a = core.project_l1(y, 2.5, method="sort")
            b = core.project_l1(y, 2.5, method=method)
            np.testing.assert_allclose(a, b, atol=1e-5)

    def test_l1_matches_quadratic_oracle(self):
        # tiny exhaustive check against a dense QP solve via scipy-free bisection
        y = jnp.asarray([3.0, -1.0, 0.5], jnp.float32)
        x = core.project_l1(y, 2.0)
        # known solution: soft threshold with theta s.t. sum|x| = 2
        # |y| = [3, 1, .5] -> theta = 0.75: [2.25, .25, 0] sums 2.5 no;
        # theta=1.25/... solve: try k=2: theta=(4-2)/2=1.0 -> [2,0,0] sum 2 OK but
        # |y2|-theta = 0 -> k=1: theta=(3-2)/1=1 -> same. x = [2, 0, 0] signed.
        np.testing.assert_allclose(x, [2.0, 0.0, 0.0], atol=1e-6)

    def test_l2_linf(self):
        y = _rand((100,), seed=3, scale=5.0)
        x2 = core.project_l2(y, 1.0)
        assert float(jnp.linalg.norm(x2)) <= 1.0 + 1e-5
        xi = core.project_linf(y, 0.3)
        assert float(jnp.max(jnp.abs(xi))) <= 0.3 + 1e-6
        np.testing.assert_allclose(xi, jnp.clip(y, -0.3, 0.3))

    def test_simplex(self):
        y = _rand((50,), seed=4)
        for method in METHODS:
            s = core.project_simplex(y, 1.0, method=method)
            assert float(jnp.min(s)) >= 0.0
            np.testing.assert_allclose(float(jnp.sum(s)), 1.0, atol=1e-5)

    @pytest.mark.parametrize("method", METHODS)
    def test_batched_radius(self, method):
        y = _rand((8, 32), seed=5, scale=2.0)
        radii = jnp.linspace(0.1, 3.0, 8)
        x = core.project_l1(y, radii, method=method)
        norms = jnp.sum(jnp.abs(x), axis=-1)
        assert bool(jnp.all(norms <= radii + 1e-4))


class TestFilterBackend:
    """The linear-time Michelot/Condat backend against the sort oracle."""

    def test_1k_randomized_agreement(self):
        # acceptance criterion: 1000 randomized cases match sort to 1e-5
        rng = np.random.default_rng(42)
        y = jnp.asarray(rng.normal(size=(1000, 64)) * 3.0, jnp.float32)
        radii = jnp.asarray(rng.uniform(0.05, 10.0, size=(1000,)), jnp.float32)
        a = core.project_l1(y, radii, method="sort")
        b = core.project_l1(y, radii, method="filter")
        np.testing.assert_allclose(a, b, atol=1e-5)

    @pytest.mark.parametrize("case", ["ties", "zeros", "feasible", "allzero",
                                      "onehot", "tiny_radius"])
    def test_adversarial_inputs(self, case):
        rng = np.random.default_rng(7)
        y = {
            "ties": jnp.asarray(np.repeat(rng.normal(size=16), 8), jnp.float32),
            "zeros": jnp.asarray(
                np.concatenate([np.zeros(64), rng.normal(size=64)]), jnp.float32),
            "feasible": jnp.asarray(rng.normal(size=128) * 1e-4, jnp.float32),
            "allzero": jnp.zeros((33,), jnp.float32),
            "onehot": jnp.zeros((128,), jnp.float32).at[17].set(5.0),
            "tiny_radius": jnp.asarray(rng.normal(size=64), jnp.float32),
        }[case]
        radius = 1e-3 if case == "tiny_radius" else 1.0
        a = core.project_l1(y, radius, method="sort")
        b = core.project_l1(y, radius, method="filter")
        np.testing.assert_allclose(a, b, atol=1e-5)
        assert float(jnp.sum(jnp.abs(b))) <= radius * (1 + 1e-4) + 1e-6

    def test_idempotent(self):
        y = _rand((257,), seed=3, scale=4.0)
        x = core.project_l1(y, 2.0, method="filter")
        x2 = core.project_l1(x, 2.0, method="filter")
        np.testing.assert_allclose(x, x2, atol=2e-6)

    def test_jit_vmap(self):
        y = _rand((6, 100), seed=9, scale=2.0)
        f = jax.jit(lambda v: core.project_l1(v, 1.0, method="filter"))
        got = jax.vmap(f)(y)
        want = core.project_l1(y, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)


class TestBackendRegistry:
    def test_resolve_and_aliases(self):
        assert core.resolve_method(None) == "sort"
        assert core.resolve_method("michelot") == "filter"
        assert core.resolve_method("condat") == "filter"
        with pytest.raises(ValueError, match="unknown l1 method"):
            core.resolve_method("quickselect")

    def test_method_info(self):
        assert core.method_info("filter").differentiable is True
        assert core.method_info("sort").differentiable is True
        assert "n" in core.method_info("bisect").complexity

    @pytest.mark.parametrize("radius", [1.5, 1000.0])  # shrinking / identity
    def test_filter_grad_matches_sort(self, radius):
        # filter is exactly differentiable: the while_loop only finds the
        # support, θ is recomputed in closed form, so its Jacobian equals the
        # sort graph's (bisect is grad-SAFE but its 64-step graph's Jacobian
        # is only approximate — checked finite below, not exact)
        y = _rand((100,), seed=11, scale=2.0)

        def loss(y, m):
            return jnp.sum(jnp.cos(core.project_l1(y, radius, method=m)))

        g_want = jax.grad(lambda y: loss(y, "sort"))(y)
        g_filter = jax.grad(lambda y: loss(y, "filter"))(y)
        np.testing.assert_allclose(g_filter, g_want, atol=1e-6)
        g_bisect = jax.grad(lambda y: loss(y, "bisect"))(y)
        assert bool(jnp.all(jnp.isfinite(g_bisect)))

    def test_register_new_backend(self):
        from repro.core.ball import L1Method, simplex_threshold_sort
        from repro.core.ball import _simplex_theta_sort
        core.register_l1_method("sort2", L1Method(
            simplex_threshold_sort, _simplex_theta_sort,
            complexity="O(n log n)", differentiable=True))
        try:
            y = _rand((64,), seed=6, scale=2.0)
            np.testing.assert_allclose(
                core.project_l1(y, 1.0, method="sort2"),
                core.project_l1(y, 1.0, method="sort"), atol=0)
            # one registration reaches every layer: bilevel picks it up too
            m = _rand((8, 12), seed=7)
            np.testing.assert_allclose(
                core.bilevel_l1inf(m, 1.0, method="sort2"),
                core.bilevel_l1inf(m, 1.0, method="sort"), atol=0)
        finally:
            from repro.core import ball as _ball
            _ball._L1_METHODS.pop("sort2", None)

    def test_canonical_norm(self):
        assert core.canonical_norm(jnp.inf) == "inf"
        assert core.canonical_norm(1) == "1"
        assert core.canonical_norm("2") == "2"
        with pytest.raises(ValueError):
            core.canonical_norm(3)


# ------------------------------------------------------------------ exact l1inf
class TestExactL1Inf:
    def test_feasibility_and_oracle_match(self):
        for seed, (n, m) in enumerate([(10, 10), (50, 20), (128, 256), (3, 500)]):
            y = _rand((n, m), seed=seed, scale=2.0)
            x = core.project_l1inf_exact(y, 1.0)
            xb = core.project_l1inf_exact_bisect(y, 1.0)
            assert float(core.l1inf_norm(x)) <= 1.0 + 1e-4
            np.testing.assert_allclose(x, xb, atol=1e-4)

    def test_identity_when_feasible(self):
        y = _rand((20, 20), seed=9) * 1e-4
        x = core.project_l1inf_exact(y, 5.0)
        np.testing.assert_allclose(x, y, atol=0)

    def test_exact_is_closer_than_bilevel(self):
        # The exact projection is the Euclidean-optimal point; bi-level is feasible
        # but generally farther. Verifies both the baseline and the paper's trade-off.
        for seed in range(4):
            y = _rand((40, 60), seed=seed, scale=1.0, dist="uniform")
            eta = 3.0
            xe = core.project_l1inf_exact(y, eta)
            xb = core.bilevel_l1inf(y, eta)
            de = float(jnp.linalg.norm(xe - y))
            db = float(jnp.linalg.norm(xb - y))
            assert de <= db + 1e-5

    def test_kkt_structure(self):
        # every column of the solution is a clip of the input at some cap t_j >= 0
        y = _rand((30, 15), seed=11, scale=2.0)
        x = core.project_l1inf_exact(y, 2.0)
        caps = jnp.max(jnp.abs(x), axis=0)
        np.testing.assert_allclose(
            x, jnp.sign(y) * jnp.minimum(jnp.abs(y), caps[None, :]), atol=1e-6
        )

    def test_dual_solver_registry(self):
        y = _rand((25, 30), seed=12, scale=2.0)
        a = core.project_l1inf_exact(y, 1.5, method="newton")
        b = core.project_l1inf_exact(y, 1.5, method="bisect")
        np.testing.assert_allclose(a, b, atol=1e-4)
        with pytest.raises(ValueError, match="unknown l1inf dual solver"):
            core.project_l1inf_exact(y, 1.5, method="secant")


# -------------------------------------------------------------------- bi-level
class TestBilevel:
    @pytest.mark.parametrize(
        "fn,p,q",
        [
            (core.bilevel_l1inf, 1, jnp.inf),
            (core.bilevel_l11, 1, 1),
            (core.bilevel_l12, 1, 2),
            (core.bilevel_l21, 2, 1),
        ],
    )
    def test_feasible(self, fn, p, q):
        y = _rand((37, 53), seed=13, scale=2.0)
        eta = 1.7
        x = fn(y, eta)
        v = core.norm_reduce(x, q, axes=0)
        norm = core.ball_norm(v, p, axis=-1)
        assert float(norm) <= eta * (1 + 1e-4) + 1e-5

    def test_bilevel_l1inf_identity_inside(self):
        y = _rand((16, 16), seed=14) * 1e-3
        x = core.bilevel_l1inf(y, 10.0)
        np.testing.assert_allclose(x, y, atol=1e-7)

    def test_bilevel_structure_is_clip(self):
        y = _rand((24, 48), seed=15, scale=2.0)
        x = core.bilevel_l1inf(y, 1.0)
        caps = jnp.max(jnp.abs(x), axis=0)
        np.testing.assert_allclose(
            x, jnp.sign(y) * jnp.minimum(jnp.abs(y), caps[None, :]), atol=1e-6
        )

    def test_bilevel_sets_whole_columns_to_zero(self):
        # structured sparsity: small-norm columns vanish entirely
        y = jnp.concatenate(
            [_rand((10, 5), seed=16, dist="uniform") * 0.01,
             _rand((10, 3), seed=17, dist="uniform") + 1.0], axis=1)
        x = core.bilevel_l1inf(y, 1.0)
        col_alive = jnp.max(jnp.abs(x), axis=0) > 0
        assert int(col_alive[:5].sum()) == 0  # the 5 weak columns die together
        assert int(col_alive[5:].sum()) > 0

    def test_axes_variant_matches_2d(self):
        y = _rand((12, 20), seed=18, scale=2.0)
        a = core.bilevel_l1inf(y, 1.3)
        b = core.bilevel_project_axes(y, 1.3, p=1, q=jnp.inf, inner_axes=(0,))
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_axes_variant_q1(self):
        y = _rand((6, 10, 14), seed=19, scale=2.0)
        x = core.bilevel_project_axes(y, 2.0, p=1, q=1, inner_axes=(0, 1))
        v = jnp.sum(jnp.abs(x), axis=(0, 1))
        assert float(jnp.sum(v)) <= 2.0 * (1 + 1e-4)

    @pytest.mark.parametrize("method", METHODS)
    def test_methods_agree(self, method):
        y = _rand((37, 53), seed=13, scale=2.0)
        for fn in (core.bilevel_l1inf, core.bilevel_l11, core.bilevel_l21):
            a = fn(y, 1.7, method="sort")
            b = fn(y, 1.7, method=method)
            np.testing.assert_allclose(a, b, atol=1e-5)


# ------------------------------------------------------------------ multilevel
class TestMultilevel:
    def test_prop_6_3_single_level_is_classic(self):
        y = _rand((9, 11), seed=20, scale=2.0)
        a = core.multilevel_project(y, [(1, 2)], 1.0)
        b = core.project_l1(y.reshape(-1), 1.0).reshape(y.shape)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_bilevel_as_multilevel(self):
        y = _rand((9, 11), seed=21, scale=2.0)
        a = core.multilevel_project(y, [(jnp.inf, 1), (1, 1)], 1.0)
        b = core.bilevel_l1inf(y, 1.0)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_trilevel_feasible(self):
        t = _rand((3, 8, 10), seed=22, scale=2.0)
        levels = [(jnp.inf, 1), (jnp.inf, 1), (1, 1)]
        x = core.trilevel_l1infinf(t, 1.2)
        assert float(core.multilevel_norm(x, levels)) <= 1.2 * (1 + 1e-4)

    def test_trilevel_l111_feasible(self):
        t = _rand((3, 8, 10), seed=23, scale=2.0)
        levels = [(1, 1), (1, 1), (1, 1)]
        x = core.trilevel_l111(t, 1.2)
        assert float(core.multilevel_norm(x, levels)) <= 1.2 * (1 + 2e-3)

    def test_level_shape_validation(self):
        t = _rand((3, 4, 5), seed=24)
        with pytest.raises(ValueError):
            core.multilevel_project(t, [(1, 2)], 1.0)

    @pytest.mark.parametrize("method", METHODS)
    def test_trilevel_methods_agree(self, method):
        t = _rand((3, 8, 10), seed=25, scale=2.0)
        a = core.trilevel_l111(t, 1.2, method="sort")
        b = core.trilevel_l111(t, 1.2, method=method)
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_work_depth_model(self):
        # Prop 6.4: depth is ~sum of log-dims, exponentially below the work term
        work, depth = core.work_depth((64, 64, 64), [(jnp.inf, 1), (jnp.inf, 1), (1, 1)])
        assert work >= 64**3
        assert depth <= 3 * (6 + 1) + 6  # ~sum log2(d) + O(levels)


# ----------------------------------------------------------------------- masks
class TestMasks:
    def test_column_mask_and_sparsity(self):
        x = jnp.asarray([[0.0, 1.0, 0.0], [0.0, 2.0, 0.0]], jnp.float32)
        m = core.column_mask(x, axis=0)
        np.testing.assert_allclose(m, [0.0, 1.0, 0.0])
        assert float(core.sparsity(x, axis=0)) == pytest.approx(100 * 2 / 3)

    def test_mask_tree_freezes_zeros(self):
        params = {"w": jnp.asarray([[0.0, 1.0], [0.0, 3.0]]), "b": jnp.ones((2,))}
        masks = core.mask_tree(params, axis=0)
        frozen = core.apply_mask(params, masks)
        np.testing.assert_allclose(frozen["w"], params["w"])
        grads = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = core.apply_mask(grads, masks)
        np.testing.assert_allclose(g["w"], [[0.0, 1.0], [0.0, 1.0]])


# ------------------------------------------------------------------- jit/vmap
class TestTransformations:
    def test_jit_and_vmap(self):
        y = _rand((4, 16, 8), seed=30, scale=2.0)
        f = jax.jit(lambda m: core.bilevel_l1inf(m, 1.0))
        a = jax.vmap(f)(y)
        for i in range(4):
            np.testing.assert_allclose(a[i], core.bilevel_l1inf(y[i], 1.0), atol=1e-6)

    def test_grad_through_bilevel(self):
        # the projection is piecewise-smooth; autodiff must produce finite grads.
        # (bisect method: this container's jaxlib cannot transpose jnp.sort)
        y = _rand((8, 8), seed=31)
        g = jax.grad(
            lambda m: jnp.sum(core.bilevel_l1inf(m, 1.0, method="bisect") ** 2)
        )(y)
        assert bool(jnp.all(jnp.isfinite(g)))
