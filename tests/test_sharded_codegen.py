"""Distributed fusion: generated Pallas kernels inside shard_map.

The ``backend="codegen"`` schedule body (kernels/codegen/distributed.py)
must be bit-for-bit interchangeable with the reference jnp body: same
collective plan (one psum/pmax per sharded ReduceLevel, replicated outer
solve, local applies), same results, same collective byte count. Coverage
mirrors test_sharded_equality.py:

* ``TestShardedCodegen*`` — in-process on an 8-device CPU mesh (the ``mesh``
  CI job; skipped on single-device hosts).
* ``TestShardedCodegenSubprocess`` — the equality matrix consolidated into
  one subprocess that forces the 8-device mesh, so tier-1 exercises the
  fused bodies on every run.

Also here: unit tests for the measured block-size autotuner
(``candidate_tile_plans`` / ``autotune_tiles``) and the ``exact_l1inf``
planner backend (satellites of the same PR).
"""

import json
import subprocess
import sys
import textwrap
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]

# same registry as test_sharded_equality.DESIGNS: >=3 distinct norm designs,
# trailing AND non-trailing sharded axes, even and uneven shards
DESIGNS = [
    ("l1inf_cols",     (32, 64), BILEVEL, (None, "model")),
    ("l1inf_rows",     (32, 64), BILEVEL, ("model", None)),
    ("l1infinf_last",  (4, 16, 64), TRILEVEL, (None, None, "model")),
    ("l1infinf_mid",   (4, 16, 64), TRILEVEL, (None, "model", None)),
    ("l12_rows",       (32, 48), [("2", 1), ("1", 1)], ("model", None)),
    ("l11_rows",       (32, 48), [("1", 1), ("1", 1)], ("model", None)),
    ("flat_l1",        (16, 24), [("1", 2)], ("model", None)),
    ("l1inf_uneven",   (32, 60), BILEVEL, (None, "model")),
    ("l11_uneven",     (30, 48), [("1", 1), ("1", 1)], ("model", None)),
]

# resumes the apply chain at level L-2 after the mesh-spanning final-l1
# (the _partial_apply_call path): final reduce level is l1 AND sharded
PARTIAL_APPLY = ("l1l1inf_partial", (4, 16, 64),
                 [("inf", 1), ("1", 1), ("1", 1)], (None, "model", None))


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * 2, jnp.float32)


def _collective_counts(fn, *args):
    """Recursively count collective primitives in fn's jaxpr."""
    names = ("psum", "pmax", "pmin", "all_gather", "all_to_all",
             "reduce_scatter")
    counts = {}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            pname = eqn.primitive.name
            if any(n in pname for n in names):
                counts[pname] = counts.get(pname, 0) + 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)
                elif isinstance(v, (tuple, list)):
                    for w in v:
                        if hasattr(w, "jaxpr"):
                            walk(w.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return counts


@multi_device
class TestShardedCodegenEquality:
    @pytest.fixture(scope="class")
    def mesh(self):
        return jax.make_mesh((8,), ("model",))

    @pytest.mark.parametrize("name,shape,levels,spec", DESIGNS + [PARTIAL_APPLY])
    def test_matches_jnp_body_and_unsharded(self, mesh, name, shape, levels,
                                            spec):
        # vs the jnp shard body the fused kernels are exact (same collective
        # plan, same arithmetic order — measured 0.0 across the matrix); vs
        # the unsharded sort oracle both sharded bodies carry the 64-iter
        # distributed bisect's convergence residual (≤4e-6 f32 here)
        from repro.core import multilevel_project, multilevel_project_sharded
        y = _rand(shape, seed=zlib.crc32(name.encode()))
        want = multilevel_project(y, levels, 2.5, method="sort")
        ref = multilevel_project_sharded(y, levels, 2.5, mesh=mesh,
                                         spec=P(*spec))
        got = multilevel_project_sharded(y, levels, 2.5, mesh=mesh,
                                         spec=P(*spec), backend="codegen",
                                         interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        np.testing.assert_allclose(got, ref, atol=1e-6)

    @pytest.mark.parametrize("spec,shape", [
        ((None, None, "model"), (3, 16, 64)),    # sharded solve axis
        ((None, "model", None), (3, 16, 60)),    # sharded final reduce, uneven
        (("model", None, None), (8, 16, 40)),    # sharded batch axis
    ])
    def test_batch_dims(self, mesh, spec, shape):
        from repro.core import multilevel_project, multilevel_project_sharded
        yb = _rand(shape, seed=3)
        want = jax.vmap(lambda w: multilevel_project(w, BILEVEL, 1.5))(yb)
        got = multilevel_project_sharded(yb, BILEVEL, 1.5, mesh=mesh,
                                         spec=P(*spec), batch_dims=1,
                                         backend="codegen", interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_two_batch_dims(self, mesh):
        from repro.core import multilevel_project, multilevel_project_sharded
        yb = _rand((2, 3, 16, 64), seed=9)
        want = jax.vmap(jax.vmap(
            lambda w: multilevel_project(w, BILEVEL, 1.5)))(yb)
        got = multilevel_project_sharded(yb, BILEVEL, 1.5, mesh=mesh,
                                         spec=P(None, None, None, "model"),
                                         batch_dims=2, backend="codegen",
                                         interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_collective_plan_identical(self, mesh):
        # the fused body must splice in EXACTLY the jnp body's collective
        # sequence — counted from the traced jaxprs, plus the static
        # byte-count model (which is a function of schedule+spec only)
        from repro.core import multilevel_project_sharded
        from repro.core.sharded import sharded_collective_bytes
        for name, shape, levels, spec in (DESIGNS[0], DESIGNS[3], DESIGNS[5]):
            y = _rand(shape, seed=11)
            jnp_counts = _collective_counts(
                lambda w: multilevel_project_sharded(
                    w, levels, 2.5, mesh=mesh, spec=P(*spec)), y)
            cg_counts = _collective_counts(
                lambda w: multilevel_project_sharded(
                    w, levels, 2.5, mesh=mesh, spec=P(*spec),
                    backend="codegen", interpret=True), y)
            assert jnp_counts == cg_counts, (name, jnp_counts, cg_counts)
            # the static byte model takes no backend argument at all: it is
            # a function of (schedule, spec) only, so it is identical for
            # both bodies by construction — pin that it stays well-defined
            bytes_model = sharded_collective_bytes(shape, levels, P(*spec),
                                                   mesh)
            assert bytes_model["schedule_bytes"] >= 0

    def test_ineligible_design_gates(self, mesh):
        # an intermediate (level < L-2) reduce axis sharded: the in-tile fold
        # cannot be split by a collective -> shardable False, explicit
        # backend="codegen" refuses rather than silently falling back
        from repro.core import multilevel_project_sharded
        from repro.kernels.codegen import distributed as dist
        shape, levels, spec = (4, 16, 64), TRILEVEL, ("model", None, None)
        assert not dist.shardable(shape, levels, spec, mesh, jnp.float32)
        with pytest.raises(ValueError, match="codegen"):
            multilevel_project_sharded(_rand(shape, 1), levels, 1.0,
                                       mesh=mesh, spec=P(*spec),
                                       backend="codegen", interpret=True)
        # ...while the eligible orientation passes the gate
        assert dist.shardable(shape, levels, (None, None, "model"), mesh,
                              jnp.float32)

    def test_projection_hook_codegen_backend(self, mesh):
        # the training hook's mesh-native leaf path accepts backend= and
        # produces the same weights with the fused body; "auto" off-TPU
        # keeps the jnp body, so all three agree
        from repro.configs.types import ProjectionSpec
        from repro.optim import projection_hook as ph
        params = {"blk": {"w_up": _rand((4, 16, 64), seed=21)}}
        pspecs = {"blk": {"w_up": P(None, None, "model")}}
        spec = ProjectionSpec(pattern="w_up", levels=(("inf", 1), ("1", 1)),
                              radius=1.5, method="bisect")
        base = ph.make_projection_hook(spec, mesh=mesh, param_specs=pspecs,
                                       backend="jnp")(params, 0)
        fused = ph.make_projection_hook(spec, mesh=mesh, param_specs=pspecs,
                                        backend="codegen")(params, 0)
        auto = ph.make_projection_hook(spec, mesh=mesh,
                                       param_specs=pspecs)(params, 0)
        np.testing.assert_allclose(fused["blk"]["w_up"], base["blk"]["w_up"],
                                   atol=1e-6)
        np.testing.assert_allclose(auto["blk"]["w_up"], base["blk"]["w_up"],
                                   atol=1e-6)

    def test_plan_backend_competes_under_auto(self, mesh):
        from jax.sharding import NamedSharding
        from repro.core import multilevel_project, plan
        plan.clear_cache()
        y = _rand((32, 64), seed=12)
        ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
        p = plan.make_plan((32, 64), jnp.float32, BILEVEL,
                           sharding=ys.sharding, interpret=True)
        assert "sharded_codegen" in p.timings_us  # it was a candidate
        want = multilevel_project(y, BILEVEL, 2.0)
        np.testing.assert_allclose(p(ys, 2.0), want, atol=1e-4)
        forced = plan.make_plan((32, 64), jnp.float32, BILEVEL,
                                sharding=ys.sharding, interpret=True,
                                method="sharded_codegen")
        np.testing.assert_allclose(forced(ys, 2.0), want, atol=1e-6)


class TestShardedCodegenSubprocess:
    """Tier-1 coverage on single-device hosts: one subprocess forces the
    8-device mesh and replays the fused-body equality matrix."""

    def test_equality_matrix(self):
        designs = [(n, s, lv, sp) for n, s, lv, sp in DESIGNS + [PARTIAL_APPLY]]
        prog = f"""
import os, zlib
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import multilevel_project, multilevel_project_sharded, plan

mesh = jax.make_mesh((8,), ("model",))
designs = {designs!r}
out = {{}}
jnp_body = {{}}
for name, shape, levels, spec in designs:
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    y = jnp.asarray(rng.normal(size=shape) * 2, jnp.float32)
    want = multilevel_project(y, levels, 2.5, method="sort")
    ref = multilevel_project_sharded(y, levels, 2.5, mesh=mesh, spec=P(*spec))
    got = multilevel_project_sharded(y, levels, 2.5, mesh=mesh, spec=P(*spec),
                                     backend="codegen", interpret=True)
    out[name] = float(jnp.abs(got - want).max())
    jnp_body[name] = float(jnp.abs(got - ref).max())

# batch_dims through the codegen body: uneven shards + sharded batch axis
rng = np.random.default_rng(3)
levels = {BILEVEL!r}
for tag, shape, spec, bd in (
        ("batch_solve_ax", (3, 16, 64), (None, None, "model"), 1),
        ("batch_fin_uneven", (3, 16, 60), (None, "model", None), 1),
        ("batch_sharded_batch", (8, 16, 40), ("model", None, None), 1)):
    yb = jnp.asarray(rng.normal(size=shape) * 2, jnp.float32)
    want = jax.vmap(lambda w: multilevel_project(w, levels, 1.5))(yb)
    got = multilevel_project_sharded(yb, levels, 1.5, mesh=mesh, spec=P(*spec),
                                     batch_dims=bd, backend="codegen",
                                     interpret=True)
    out[tag] = float(jnp.abs(got - want).max())

# gating: intermediate reduce axis sharded must refuse, not fall back
from repro.kernels.codegen import distributed as dist
out["gate_shardable"] = not dist.shardable(
    (4, 16, 64), {TRILEVEL!r}, ("model", None, None), mesh, jnp.float32)
try:
    multilevel_project_sharded(jnp.zeros((4, 16, 64)), {TRILEVEL!r}, 1.0,
                               mesh=mesh, spec=P("model", None, None),
                               backend="codegen", interpret=True)
    out["gate_raises"] = False
except ValueError:
    out["gate_raises"] = True

# planner: sharded_codegen competes under auto on the sharded interpret key
plan.clear_cache()
y = jnp.asarray(np.random.default_rng(12).normal(size=(32, 64)) * 2,
                jnp.float32)
ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
p = plan.make_plan((32, 64), jnp.float32, levels, sharding=ys.sharding,
                   interpret=True)
out["plan_candidate"] = "sharded_codegen" in p.timings_us
forced = plan.make_plan((32, 64), jnp.float32, levels, sharding=ys.sharding,
                        interpret=True, method="sharded_codegen")
out["plan_forced_diff"] = float(jnp.abs(
    forced(ys, 2.0) - multilevel_project(y, levels, 2.0)).max())
print("RESULT" + json.dumps({{"solver": out, "jnp_body": jnp_body}}))
"""
        res = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(prog)],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu"})
        assert res.returncode == 0, res.stderr[-3000:]
        payload = json.loads(res.stdout.split("RESULT", 1)[1])
        out = payload["solver"]
        assert out.pop("gate_shardable") is True
        assert out.pop("gate_raises") is True
        assert out.pop("plan_candidate") is True
        # fused body vs the jnp shard body: exact (same collective plan)
        for name, diff in payload["jnp_body"].items():
            assert diff < 1e-6, (name, diff)
        # vs the unsharded sort oracle: 64-iter bisect convergence residual
        for name, diff in out.items():
            assert diff < 1e-5, (name, diff)


class TestBlockAutotuner:
    """The measured block-size autotuner (kernels/codegen): candidate grid,
    caching, and the tuned-build entry point."""

    def test_candidate_grid_contains_default_first(self):
        from repro.core.schedule import compile_schedule
        from repro.kernels.codegen.tiling import (candidate_tile_plans,
                                                  plan_tiles)
        sched = compile_schedule((64, 256), BILEVEL)
        cands = candidate_tile_plans(sched, jnp.float32)
        assert len(cands) >= 1
        assert cands[0] == plan_tiles(sched, jnp.float32)
        # all candidates plan the same canonical shape, deduped
        assert len(set(cands)) == len(cands)
        for c in cands:
            assert c.canon_shape == cands[0].canon_shape

    def test_l1_resident_pins_block_n(self):
        # the l1 fold needs the whole group resident: only block_m may vary
        from repro.core.schedule import compile_schedule
        from repro.kernels.codegen.tiling import candidate_tile_plans
        sched = compile_schedule((64, 256), [("1", 1), ("1", 1)])
        cands = candidate_tile_plans(sched, jnp.float32)
        assert len({c.block_n for c in cands}) == 1

    def test_autotune_caches_and_builds(self):
        from repro.core import multilevel_project
        from repro.kernels import codegen
        codegen.clear_tile_cache()
        tp = codegen.autotune_tiles((16, 64), BILEVEL, jnp.float32,
                                    interpret=True)
        tp2 = codegen.autotune_tiles((16, 64), BILEVEL, jnp.float32,
                                     interpret=True)
        assert tp is tp2  # cached
        fn = codegen.build_tuned((16, 64), BILEVEL, jnp.float32,
                                 interpret=True)
        y = _rand((16, 64), seed=21)
        np.testing.assert_allclose(fn(y, 2.0),
                                   multilevel_project(y, BILEVEL, 2.0),
                                   atol=1e-5)

    def test_measured_autotune_picks_a_candidate(self):
        # force measurement even in interpret mode: the winner must come from
        # the candidate grid and produce correct results
        from repro.core import multilevel_project
        from repro.core.schedule import compile_schedule
        from repro.kernels import codegen
        from repro.kernels.codegen.tiling import candidate_tile_plans
        codegen.clear_tile_cache()
        tp = codegen.autotune_tiles((16, 48), BILEVEL, jnp.float32,
                                    interpret=True, measure=True)
        sched = compile_schedule((16, 48), BILEVEL)
        assert tp in candidate_tile_plans(sched, jnp.float32)
        fn = codegen.build((16, 48), BILEVEL, jnp.float32, interpret=True,
                           tile_plan=tp)
        y = _rand((16, 48), seed=22)
        np.testing.assert_allclose(fn(y, 1.5),
                                   multilevel_project(y, BILEVEL, 1.5),
                                   atol=1e-5)

    def test_explicit_tile_plan_equality(self):
        # every candidate block size computes the same projection
        from repro.core import multilevel_project
        from repro.core.schedule import compile_schedule
        from repro.kernels import codegen
        from repro.kernels.codegen.tiling import candidate_tile_plans
        sched = compile_schedule((32, 96), BILEVEL)
        y = _rand((32, 96), seed=23)
        want = multilevel_project(y, BILEVEL, 2.0)
        for tp in candidate_tile_plans(sched, jnp.float32):
            fn = codegen.build((32, 96), BILEVEL, jnp.float32,
                               interpret=True, tile_plan=tp)
            np.testing.assert_allclose(fn(y, 2.0), want, atol=1e-5,
                                       err_msg=str(tp))


class TestExactL1InfBackend:
    """core/exact_l1inf registered as a planner backend: the EXACT l1,inf
    projection (Chu et al.) competing under method="auto" on bi-level keys."""

    def test_registered_and_available(self):
        from repro.core import plan
        plan.clear_cache()
        key = plan.PlanKey(shape=(6, 10), dtype="float32",
                           levels=(("inf", 1), ("1", 1)),
                           radius_kind="scalar", device="cpu")
        assert "exact_l1inf" in plan._candidates(key)
        # tri-level and non-2D keys are out of scope for the exact solver
        key3 = plan.PlanKey(shape=(2, 6, 10), dtype="float32",
                            levels=(("inf", 1), ("inf", 1), ("1", 1)),
                            radius_kind="scalar", device="cpu")
        assert "exact_l1inf" not in plan._candidates(key3)

    def test_explicit_plan_close_to_bilevel(self):
        # the exact projection is a DIFFERENT operator from the bi-level
        # relaxation, but both land on the same l1,inf ball: compare at the
        # loose tolerance of the operator gap, and check exact feasibility
        from repro.core import multilevel_project, plan
        from repro.core.exact_l1inf import l1inf_norm
        plan.clear_cache()
        y = _rand((6, 10), seed=31)
        p = plan.make_plan((6, 10), jnp.float32,
                           [("inf", 1), ("1", 1)], method="exact_l1inf")
        got = p(y, 2.0)
        assert float(l1inf_norm(got)) <= 2.0 * (1 + 1e-5)
        ref = multilevel_project(y, [("inf", 1), ("1", 1)], 2.0)
        np.testing.assert_allclose(got, ref, atol=0.5)

    def test_auto_still_picks_a_generic_method(self):
        # regression guard: the exact solver is 3-30x slower than the generic
        # solvers on CPU — auto must keep choosing a ball method (the
        # assertion test_plan.py::test_auto_matches_fixed relies on)
        from repro.core import plan
        plan.clear_cache()
        p = plan.make_plan((64, 512), jnp.float32, [("inf", 1), ("1", 1)])
        assert p.method != "exact_l1inf"
