"""Projection planner (core.plan): correctness vs the unplanned recursion,
the multilevel edge cases the planner must validate, autotune behavior, and
plan/executable cache semantics (second call does not re-trace)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ball, bilevel, multilevel, plan

BILEVEL = [("inf", 1), ("1", 1)]
TRILEVEL = [("inf", 1), ("inf", 1), ("1", 1)]


def _rand(shape, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.fixture(autouse=True)
def _fresh_planner():
    plan.clear_cache()
    yield
    plan.clear_cache()


class TestMakePlan:
    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    @pytest.mark.parametrize("shape,levels", [
        ((6, 10), BILEVEL),
        ((3, 6, 10), TRILEVEL),
        ((4, 5), [("2", 1), ("1", 1)]),
    ])
    def test_matches_multilevel(self, shape, levels, method):
        y = _rand(shape, seed=hash((shape, method)) % 2**31)
        p = plan.make_plan(shape, jnp.float32, levels, method=method)
        want = multilevel.multilevel_project(y, levels, 1.5, method=method)
        np.testing.assert_allclose(p(y, 1.5), want, atol=1e-5)

    def test_auto_matches_fixed(self):
        y = _rand((6, 10), seed=1)
        p = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="auto")
        assert p.method in ball.available_methods()
        assert set(p.timings_us) >= set(ball.available_methods())
        want = multilevel.multilevel_project(y, BILEVEL, 1.0, method=p.method)
        np.testing.assert_allclose(p(y, 1.0), want, atol=1e-5)

    def test_degenerate_single_level(self):
        # |ν| = 1: the plan is the classical flat projection (Prop 6.3)
        y = _rand((4, 8), seed=2)
        p = plan.make_plan((4, 8), jnp.float32, [("1", 2)], method="sort")
        want = ball.project_l1(y.reshape(-1), 1.0).reshape(4, 8)
        np.testing.assert_allclose(p(y, 1.0), want, atol=1e-6)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    def test_radius_zero_projects_to_origin(self, method):
        y = _rand((5, 7), seed=3)
        p = plan.make_plan((5, 7), jnp.float32, BILEVEL, method=method)
        np.testing.assert_allclose(p(y, 0.0), jnp.zeros((5, 7)), atol=1e-6)

    @pytest.mark.parametrize("method", ["sort", "bisect", "filter"])
    def test_ties_at_the_max(self, method):
        # a level whose ∞-reduce sees exact ties must stay exact + feasible
        y = jnp.asarray([[2.0, 2.0, -2.0], [2.0, -2.0, 2.0]], jnp.float32)
        p = plan.make_plan((2, 3), jnp.float32, BILEVEL, method=method)
        got = p(y, 1.0)
        want = multilevel.multilevel_project(y, BILEVEL, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)
        assert float(multilevel.multilevel_norm(got, BILEVEL)) <= 1.0 + 1e-5

    def test_batch_radius_kind(self):
        ys = jnp.stack([_rand((4, 6), seed=s) for s in range(3)])
        radii = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)
        p = plan.make_plan((4, 6), jnp.float32, BILEVEL,
                           radius_kind="batch", method="sort")
        out = p(ys, radii)
        for i in range(3):
            want = multilevel.multilevel_project(ys[i], BILEVEL, radii[i],
                                                 method="sort")
            np.testing.assert_allclose(out[i], want, atol=1e-6)

    def test_validation_errors(self):
        with pytest.raises(ValueError, match="covers"):
            plan.make_plan((4, 6, 2), jnp.float32, BILEVEL)
        with pytest.raises(ValueError, match="unknown projection backend"):
            plan.make_plan((4, 6), jnp.float32, BILEVEL, method="nope")
        with pytest.raises(ValueError, match="radius_kind"):
            plan.make_plan((4, 6), jnp.float32, BILEVEL, radius_kind="maybe")
        with pytest.raises(ValueError, match="not available"):
            # generated kernel ineligible off-TPU without interpret
            plan.make_plan((4, 6), jnp.float32, BILEVEL, method="codegen")
        p = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        with pytest.raises(ValueError, match="built for shape"):
            p(jnp.zeros((4, 7)), 1.0)
        with pytest.raises(ValueError, match="built for dtype"):
            p(jnp.zeros((4, 6), jnp.bfloat16), 1.0)


class TestPlanCache:
    def test_plan_cache_hit_returns_same_object(self):
        p1 = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        p2 = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        assert p1 is p2

    def test_second_call_does_not_retrace(self):
        y = _rand((4, 6), seed=4)
        p = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        p(y, 1.0)
        assert p.trace_count == 1
        p(y, 2.0)
        p(y + 1.0, 0.5)
        assert p.trace_count == 1  # same shape/dtype: cached lowering reused

    def test_auto_shares_winner_executable(self):
        y = _rand((4, 6), seed=5)
        pa = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto")
        traces_after_autotune = pa.trace_count
        assert traces_after_autotune == 1  # autotune itself traced it once
        pf = plan.make_plan((4, 6), jnp.float32, BILEVEL, method=pa.method)
        pa(y, 1.0)
        pf(y, 1.0)
        assert pa.trace_count == traces_after_autotune  # shared, no re-trace
        assert pf.trace_count == pa.trace_count

    def test_auto_winner_cached(self):
        pa = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto")
        info = plan.cache_info()
        assert info["auto_winners"] == 1
        pb = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto")
        assert pa is pb
        assert plan.cache_info()["auto_winners"] == 1

    def test_clear_cache(self):
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        assert plan.cache_info()["plans"] == 1
        plan.clear_cache()
        info = plan.cache_info()
        assert info["plans"] == info["executables"] == 0
        assert info["auto_winners"] == 0

    def test_cache_info_hit_miss_counters(self):
        # generation counters: first build is a miss, the repeat is a hit
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        info = plan.cache_info()
        assert info["plan_misses"] == 1 and info["plan_hits"] == 0
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        info = plan.cache_info()
        assert info["plan_misses"] == 1 and info["plan_hits"] == 1
        # a different key is another miss, not a hit
        plan.make_plan((4, 8), jnp.float32, BILEVEL, method="sort")
        assert plan.cache_info()["plan_misses"] == 2

    def test_cache_info_retrace_counter(self):
        y = _rand((4, 6), seed=40)
        p = plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        p(y, 1.0)
        p(y, 2.0)
        assert plan.cache_info()["retraces"] == 0  # jit cache held

    def test_cache_info_autotune_counters(self):
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto")
        info = plan.cache_info()
        assert info["autotune_runs"] == 1 and info["autotune_hits"] == 0
        # an identical repeat hits the plan memo BEFORE the winner lookup
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto")
        info = plan.cache_info()
        assert info["autotune_runs"] == 1 and info["autotune_hits"] == 0
        assert info["plan_hits"] == 1
        # a plan-memo miss for the same PlanKey (different donate flag)
        # reuses the cached verdict instead of re-running the shoot-out
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="auto",
                       donate=True)
        info = plan.cache_info()
        assert info["autotune_runs"] == 1 and info["autotune_hits"] == 1

    def test_evictions_cumulative_across_clear(self):
        # hit/miss counters reset with the generation; evictions are
        # Prometheus-counter cumulative (the clear IS the eviction event)
        plan.clear_cache()
        base = plan.cache_info()["evictions"]
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
        n_cached = (plan.cache_info()["plans"]
                    + plan.cache_info()["executables"]
                    + plan.cache_info()["auto_winners"])
        plan.clear_cache()
        info = plan.cache_info()
        assert info["evictions"] == base + n_cached
        assert info["plan_hits"] == info["plan_misses"] == 0

    def test_cache_info_mirrors_to_obs_gauge(self):
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.Registry()
        prev = obs_metrics.set_registry(reg)
        try:
            plan.make_plan((4, 6), jnp.float32, BILEVEL, method="sort")
            info = plan.cache_info()
            gauge = reg.gauge("plan_cache", labels=("stat",))
            for name, v in info.items():
                assert gauge.labels(stat=name).value == v
        finally:
            obs_metrics.set_registry(prev)


class TestAutoThreading:
    def test_multilevel_auto_eager(self):
        y = _rand((3, 6, 10), seed=6)
        got = multilevel.multilevel_project(y, TRILEVEL, 1.0, method="auto")
        want = multilevel.multilevel_project(y, TRILEVEL, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_multilevel_auto_under_jit(self):
        y = _rand((6, 10), seed=7)
        fn = jax.jit(lambda y: multilevel.multilevel_project(
            y, BILEVEL, 1.0, method="auto"))
        want = multilevel.multilevel_project(y, BILEVEL, 1.0, method="sort")
        np.testing.assert_allclose(fn(y), want, atol=1e-5)

    def test_bilevel_auto(self):
        y = _rand((6, 10), seed=8)
        got = bilevel.bilevel_l1inf(y, 1.0, method="auto")
        want = bilevel.bilevel_l1inf(y, 1.0, method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_bilevel_axes_auto(self):
        y = _rand((5, 4, 6), seed=9)
        got = bilevel.bilevel_project_axes(y, 1.0, inner_axes=(1,),
                                           method="auto")
        want = bilevel.bilevel_project_axes(y, 1.0, inner_axes=(1,),
                                            method="sort")
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_best_l1_method_is_generic(self):
        assert plan.best_l1_method(512) in ball.available_methods()


class TestCodegenBackendPlans:
    """The generated-kernel backend through the planner; the full equality
    matrix lives in tests/test_codegen.py."""

    def test_codegen_trilevel_via_plan(self):
        y = _rand((3, 17, 130), seed=10)
        p = plan.make_plan((3, 17, 130), jnp.float32, TRILEVEL,
                           method="codegen", interpret=True)
        want = multilevel.trilevel_l1infinf(y, 1.0, method="bisect")
        np.testing.assert_allclose(p(y, 1.0), want, atol=1e-5)

    def test_codegen_bilevel_via_plan(self):
        y = _rand((16, 130), seed=11)
        p = plan.make_plan((16, 130), jnp.float32, BILEVEL,
                           method="codegen", interpret=True)
        want = bilevel.bilevel_l1inf(y, 1.0, method="bisect")
        np.testing.assert_allclose(p(y, 1.0), want, atol=1e-5)

    def test_hand_written_backends_demoted(self):
        # the golden kernels no longer compete as planner backends
        with pytest.raises(ValueError, match="unknown projection backend"):
            plan.make_plan((16, 130), jnp.float32, BILEVEL,
                           method="fused_bilevel", interpret=True)


class TestDonationAndBatchNative:
    """Serving-facing planner features: donated executables (in-place
    projection for the engine) and batch-native backend gating."""

    def test_donating_plan_consumes_input(self):
        p = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="sort",
                           donate=True)
        y = _rand((6, 10), seed=20)
        want = multilevel.multilevel_project(y, BILEVEL, 1.0, method="sort")
        out = p(y, 1.0)
        np.testing.assert_allclose(out, want, atol=1e-6)
        assert y.is_deleted()          # buffer was donated to the executable

    def test_plain_plan_preserves_input(self):
        p = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="sort")
        y = _rand((6, 10), seed=21)
        p(y, 1.0)
        assert not y.is_deleted()

    def test_donating_and_plain_plans_are_distinct(self):
        a = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="sort")
        b = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="sort",
                           donate=True)
        assert a is not b
        assert plan.make_plan((6, 10), jnp.float32, BILEVEL,
                              method="sort", donate=True) is b

    def test_donating_batch_plan(self):
        p = plan.make_plan((6, 10), jnp.float32, BILEVEL,
                           radius_kind="batch", method="sort", donate=True)
        ys = jnp.stack([_rand((6, 10), seed=s) for s in range(3)])
        radii = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)
        refs = [multilevel.multilevel_project(ys[i], BILEVEL, radii[i],
                                              method="sort")
                for i in range(3)]
        out = p(ys, radii)
        assert ys.is_deleted()
        for i in range(3):
            np.testing.assert_allclose(out[i], refs[i], atol=1e-6)

    def test_is_batch_native_registry(self):
        assert plan.is_batch_native("codegen_batch")
        assert not plan.is_batch_native("codegen")
        assert not plan.is_batch_native("sort")
        assert not plan.is_batch_native("auto")

    def test_validate_backend_radius_kind_gate(self):
        # codegen_batch validates only for batch keys
        assert plan.validate_backend((8, 16), jnp.float32, BILEVEL,
                                     "codegen_batch", interpret=True,
                                     radius_kind="batch") == "codegen_batch"
        with pytest.raises(ValueError, match="not available"):
            plan.validate_backend((8, 16), jnp.float32, BILEVEL,
                                  "codegen_batch", interpret=True,
                                  radius_kind="scalar")


class TestTrainingGradKeys:
    """grad=True plan keys: the autotuner times value_and_grad, verdicts are
    cached separately from forward keys, and the generated-kernel backend
    (which now carries its own backward) is eligible for them."""

    def test_grad_key_is_distinct_and_differentiable(self):
        fwd = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="auto")
        trn = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="auto",
                             grad=True)
        assert fwd is not trn
        assert fwd.key.grad is False and trn.key.grad is True
        assert trn.method in ball.available_methods()
        assert set(trn.timings_us) >= set(ball.available_methods())
        # the plan executable stays differentiable (it IS the forward)
        y = _rand((6, 10), seed=30)
        g = jax.grad(lambda v: jnp.sum(trn._exec.fn(v, jnp.float32(1.5)) ** 2))(y)
        assert np.all(np.isfinite(g))

    def test_grad_verdict_cached_per_key(self):
        a = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="auto",
                           grad=True)
        b = plan.make_plan((6, 10), jnp.float32, BILEVEL, method="auto",
                           grad=True)
        assert a is b
        info = plan.cache_info()
        assert info["auto_winners"] >= 1

    def test_codegen_eligible_for_grad_keys(self):
        # fixed-backend grad key: codegen builds, and differentiating through
        # the plan matches the sort oracle (the generated backward)
        p = plan.make_plan((16, 130), jnp.float32, BILEVEL, method="codegen",
                           interpret=True, grad=True)
        y = _rand((16, 130), seed=31)
        got = jax.grad(lambda v: jnp.sum(p._exec.fn(v, jnp.float32(1.0)) ** 2))(y)
        want = jax.grad(lambda v: jnp.sum(multilevel.multilevel_project(
            v, BILEVEL, 1.0, method="sort") ** 2))(y)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_plankey_positional_backcompat(self):
        # the grad field is trailing + defaulted: pre-existing positional
        # constructions (tests, serving) must keep meaning grad=False
        key = plan.PlanKey((16, 32), "float32", (("inf", 1), ("1", 1)),
                           "scalar", "cpu")
        assert key.grad is False and key.interpret is False

    def test_best_l1_method_grad(self):
        m = plan.best_l1_method(64, jnp.float32, grad=True)
        assert m in ball.available_methods()

    def test_sharded_backend_excluded_from_grad_keys(self):
        # _sharded_available gates grad keys out (mesh training keeps the hook)
        key = plan.PlanKey((8, 16), "float32", (("inf", 1), ("1", 1)),
                           "scalar", "cpu", False,
                           plan.ShardingKey((("d", 2),), (0, 1), (None, "d")),
                           True)
        assert not plan._SPECIALIZED["sharded"].available(key)
