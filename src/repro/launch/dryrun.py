import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k --mesh single --out experiments/dryrun

For each cell this prints/records compiled.memory_analysis() (fits?),
cost_analysis() (FLOPs/bytes for §Roofline), and the HLO collective schedule.
The two required meshes: 16×16 single pod, 2×16×16 multi-pod (the 'pod' axis
must shard). Results are streamed to  <out>/<arch>__<shape>__<mesh>.json so a
crashed/killed sweep resumes where it left off (--resume).
"""

import argparse
import gzip
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.types import SHAPES
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RF


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             verbose: bool = True):
    cfg = registry.get_arch(arch)
    shape = SHAPES[shape_name]
    skip = SP.cell_skipped(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "time": time.time()}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        cell = SP.build_cell(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(cell["fn"],
                             in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"],
                             donate_argnums=cell["donate"] or None)
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            hlo_text = compiled.as_text()
            roof = RF.analyze(compiled, chips, hlo_text=hlo_text)
        # cache the SPMD HLO so the cost model can be re-run offline
        hdir = os.path.join(out_dir, "hlo")
        os.makedirs(hdir, exist_ok=True)
        with gzip.open(os.path.join(
                hdir, f"{arch}__{shape_name}__{mesh_kind}.txt.gz"), "wt") as f:
            f.write(hlo_text)

        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            chips=chips,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            roofline=roof.as_dict(),
        )
        # analytic MODEL_FLOPS for the useful-compute ratio
        tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" \
            else shape.global_batch
        napi = _param_count(cfg)
        rec["model_flops"] = RF.model_flops(
            napi["active"], tokens, "train" if shape.kind == "train" else "serve")
        rec["params_total"] = napi["total"]
        rec["params_active"] = napi["active"]
        rec["useful_ratio"] = (rec["model_flops"] / roof.flops_global
                               if roof.flops_global else None)
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] OK "
                  f"compile={t_compile:.0f}s "
                  f"mem/dev={_fmt_bytes(_per_dev_bytes(rec))} "
                  f"terms: C={roof.t_compute*1e3:.1f}ms "
                  f"M={roof.t_memory*1e3:.1f}ms "
                  f"K={roof.t_collective*1e3:.1f}ms -> {roof.bottleneck}")
    except Exception as e:  # noqa: BLE001 - report and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] FAIL {rec['error']}",
                  file=sys.stderr)
    return rec


def _param_count(cfg):
    from repro import models
    from repro.models import params as PM
    tpl = models.get(cfg).template(cfg)
    total = PM.count_params(tpl)
    active = total
    if cfg.moe is not None:
        # subtract inactive routed experts
        mo = cfg.moe
        n_moe_layers = cfg.n_layers - mo.first_dense
        per_expert = 3 * cfg.d_model * mo.d_expert
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * per_expert
        active = total - inactive
    return {"total": int(total), "active": int(active)}


def _per_dev_bytes(rec):
    m = rec.get("memory", {})
    # memory_analysis is already per-device for SPMD executables
    vals = [v for k, v in m.items() if isinstance(v, (int, float))
            and k in ("argument_bytes", "temp_bytes")]
    return sum(vals) if vals else 0


def _fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned archs)")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose json already exists")
    args = ap.parse_args()

    archs = registry.ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_kind}.json")
                if args.resume and os.path.exists(path):
                    continue
                rec = run_cell(arch, shape, mesh_kind, args.out)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"dry-run sweep done: {n_ok} ok/skip, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
