import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower a train cell with a named Tuning variant
and record the roofline delta vs baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell kimi_ep \
        --out experiments/hillclimb
"""

import argparse
import dataclasses
import json
import sys
import time

import jax

from repro.configs import registry
from repro.configs.types import SHAPES
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RF

# (arch, shape, variant-name, Tuning overrides)
VARIANTS = {
    # ---- cell A: kimi-k2 train_4k (most collective-bound baseline) ----
    "kimi_ep2d": ("kimi-k2-1t-a32b", "train_4k", dict(ep_2d=True)),
    "kimi_scatter": ("kimi-k2-1t-a32b", "train_4k", dict(moe_dispatch="scatter")),
    "kimi_ep2d_scatter": ("kimi-k2-1t-a32b", "train_4k",
                          dict(ep_2d=True, moe_dispatch="scatter")),
    "kimi_ep2d_scatter_mb32": ("kimi-k2-1t-a32b", "train_4k",
                               dict(ep_2d=True, moe_dispatch="scatter",
                                    microbatch=32)),
    # ---- cell B: xlstm train_4k (worst compute fraction) ----
    "xlstm_chunk128": ("xlstm-1.3b", "train_4k", dict(xlstm_chunk=128)),
    "xlstm_chunk256": ("xlstm-1.3b", "train_4k", dict(xlstm_chunk=256)),
    "xlstm_chunk512": ("xlstm-1.3b", "train_4k", dict(xlstm_chunk=512)),
    # ---- cell C: stablelm train_4k (paper-representative: projection on) ----
    "stablelm_probsbf16": ("stablelm-1.6b", "train_4k",
                           dict(attn_probs_bf16=True)),
    "stablelm_chunk2048": ("stablelm-1.6b", "train_4k", dict(attn_chunk=2048)),
    "stablelm_probsbf16_c2048": ("stablelm-1.6b", "train_4k",
                                 dict(attn_probs_bf16=True, attn_chunk=2048)),
    "stablelm_mb64": ("stablelm-1.6b", "train_4k",
                      dict(attn_probs_bf16=True, microbatch=64)),
    # mesh-native projection hook on every MLP weight: the schedule executor
    # projects FSDP/TP-sharded leaves in place (collective bytes = aggregates
    # only, DESIGN.md §3) — the roofline delta vs stablelm_* baselines is the
    # measured cost of widening the paper's constraint to the whole MLP
    "stablelm_proj_all": ("stablelm-1.6b", "train_4k",
                          dict(projection_pattern=r"(w_up|w_gate|w_down)")),
    "kimi_scatter_mb32": ("kimi-k2-1t-a32b", "train_4k",
                          dict(moe_dispatch="scatter", microbatch=32)),
    "kimi_scatter_mb64": ("kimi-k2-1t-a32b", "train_4k",
                          dict(moe_dispatch="scatter", microbatch=64)),
    "xlstm_chunk128_mb64": ("xlstm-1.3b", "train_4k",
                            dict(xlstm_chunk=128, microbatch=64)),
    "xlstm_shard_r": ("xlstm-1.3b", "train_4k", dict(xlstm_shard_r=True)),
    "xlstm_shard_r_chunk128": ("xlstm-1.3b", "train_4k",
                               dict(xlstm_shard_r=True, xlstm_chunk=128)),
    # beyond-paper for the deepseek prefill dispatch blow-up
    "deepseek_scatter": ("deepseek-v3-671b", "train_4k",
                         dict(moe_dispatch="scatter")),
    # GSP-style whole-network sparsification: EVERY ≥2D weight projected per
    # step (attention, embeddings, vocab head — not just the MLP). Roofline
    # delta vs stablelm_proj_all = the marginal collective cost of the
    # remaining leaves through the mesh executor.
    "stablelm_gsp_all": ("stablelm-1.6b", "train_4k",
                         dict(projection_pattern=r".*")),
    # the SAE factory's own train cell (specs.sae_factory_cell): d_model=2048
    # activations in, 8× overcomplete dictionary, encoder projected per step
    "sae_factory": ("sae_factory", "train_4k", dict()),
    # head-structured factory (§6): 3-D encoder, tri-level l1,inf,inf ball —
    # roofline delta vs sae_factory = the extra reduce level's collective cost
    "sae_factory_heads8": ("sae_factory", "train_4k", dict(heads=8)),
}


def _sae_factory_cell(mesh, heads=1):
    return SP.sae_factory_cell(2048, mesh, expansion=8,
                               batch=4096, microbatch=512, heads=heads)


def run_variant(name, out_dir):
    arch, shape_name, overrides = VARIANTS[name]
    mesh = make_production_mesh()
    t0 = time.time()
    if arch == "sae_factory":
        shape = SHAPES[shape_name]
        cell = _sae_factory_cell(mesh, heads=overrides.get("heads", 1))
    else:
        cfg = registry.get_arch(arch)
        shape = SHAPES[shape_name]
        tune = dataclasses.replace(SP.tuning_for(cfg), **overrides)
        cell = SP.build_cell(cfg, shape, mesh, tune=tune)
    with mesh:
        jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                         out_shardings=cell["out_shardings"],
                         donate_argnums=cell["donate"] or None)
        compiled = jitted.lower(*cell["args"]).compile()
        mem = compiled.memory_analysis()
        roof = RF.analyze(compiled, mesh.devices.size)
    rec = dict(
        variant=name, arch=arch, shape=shape_name,
        overrides={k: str(v) for k, v in overrides.items()},
        compile_s=round(time.time() - t0, 1),
        memory={"argument_bytes": mem.argument_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes},
        roofline=roof.as_dict(),
    )
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    rf = rec["roofline"]
    print(f"[{name}] C={rf['t_compute']*1e3:.0f}ms M={rf['t_memory']*1e3:.0f}ms "
          f"K={rf['t_collective']*1e3:.0f}ms temp/dev="
          f"{mem.temp_size_in_bytes/2**30:.1f}GB -> {rf['bottleneck']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    help="variant name or 'all' or comma list")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    names = list(VARIANTS) if args.cell == "all" else args.cell.split(",")
    fails = 0
    for n in names:
        try:
            run_variant(n, args.out)
        except Exception as e:  # noqa: BLE001
            fails += 1
            print(f"[{n}] FAIL {type(e).__name__}: {e}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
