"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state (the dry-run must set XLA_FLAGS first)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per v5e pod; the multi-pod mesh adds a leading
    2-pod data-parallel axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))
