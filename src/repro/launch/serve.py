"""Serving launcher: batched greedy decoding against a (random- or
checkpoint-initialized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --batch 8 --prompt-len 16 --new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import registry
from repro.models import params as PM
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.runtime import CheckpointManager
from repro.serving import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the decode here")
    ap.add_argument("--metrics-out", default="",
                    help="write the obs-registry snapshot (JSON lines) here")
    args = ap.parse_args()

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.get_arch(args.arch))
    api = models.get(cfg)
    if args.ckpt:
        tree, _ = CheckpointManager(args.ckpt).restore()
        params = tree["params"]
    else:
        params = PM.init_params(api.template(cfg), jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    with obs_profile.capture(args.profile_dir):
        out = lm.generate(params, cfg, prompts, max_new=args.new)
        out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"{args.batch} requests × {args.new} new tokens in {dt:.1f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print("first request:", np.asarray(out[0]))
    if args.metrics_out:
        obs_metrics.get_registry().write_jsonl(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.profile_dir:
        print(f"profiler trace -> {args.profile_dir} "
              f"({len(obs_profile.trace_files(args.profile_dir))} files)")


if __name__ == "__main__":
    main()
