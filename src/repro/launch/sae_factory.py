"""CLI for the sparse-SAE training factory (training/sae_factory.py).

    PYTHONPATH=src python -m repro.launch.sae_factory \
        --arch stablelm-1.6b --out /tmp/sae_run --layers 0,2 \
        --train-steps 200 --expansion 8

Runs harvest → projected SAE training (one per layer × seed) → MMCS
cross-comparison and writes ``summary.json`` into ``--out``. Add ``--gsp``
to also run the whole-network GSP sparsification pass (every weight of the
LM projected per step; give it a multi-device host via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise the mesh
executor path).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--out", required=True)
    ap.add_argument("--site", default="resid", choices=["resid", "mlp"])
    ap.add_argument("--layers", default="",
                    help="comma list of layer indices; empty = all")
    ap.add_argument("--harvest-steps", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--expansion", type=int, default=4)
    ap.add_argument("--radius", type=float, default=1.0)
    ap.add_argument("--heads", type=int, default=1,
                    help=">1: head-structured dictionary — 3-D encoder "
                         "projected onto the tri-level l1,inf,inf ball")
    ap.add_argument("--checkpoint", default="",
                    help="checkpoint dir to harvest from (runtime/checkpoint "
                         "layout); default: seeded init weights")
    ap.add_argument("--seeds", default="0,1")
    ap.add_argument("--full", action="store_true",
                    help="full-size arch (default: smoke config)")
    ap.add_argument("--gsp", action="store_true",
                    help="also run whole-network GSP sparsification")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the factory run "
                         "(projection stages appear as proj/* named scopes)")
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_host_mesh
    from repro.obs import metrics as obs_metrics
    from repro.obs import profile as obs_profile
    from repro.training import sae_factory as F

    import jax

    fcfg = F.SAEFactoryConfig(
        arch=args.arch, smoke=not args.full, site=args.site,
        layers=tuple(int(x) for x in args.layers.split(",") if x) or None,
        harvest_steps=args.harvest_steps, train_steps=args.train_steps,
        expansion=args.expansion, radius=args.radius, heads=args.heads)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    seeds = tuple(int(s) for s in args.seeds.split(","))
    lm_params = None
    if args.checkpoint:
        from repro.runtime.checkpoint import CheckpointManager

        tree, manifest = CheckpointManager(args.checkpoint).restore()
        if tree is None:
            print(f"no checkpoint found under {args.checkpoint}",
                  file=sys.stderr)
            return 1
        # training states store {"params", "opt"}; bare param trees pass as-is
        lm_params = tree["params"] if (isinstance(tree, dict)
                                       and "params" in tree) else tree
        print(f"harvesting from checkpoint step "
              f"{manifest.get('step', '?')} at {args.checkpoint}")
    with obs_profile.capture(args.profile_dir):
        summary = F.run_factory(fcfg, out, seeds=seeds, lm_params=lm_params)
        if args.gsp:
            n_dev = jax.device_count()
            mesh = make_host_mesh(1, n_dev) if n_dev > 1 else None
            summary["gsp"] = F.gsp_whole_network(args.arch, mesh=mesh)
    obs_metrics.get_registry().write_jsonl(out / "metrics.jsonl")
    # json keys must be strings; layers come out as ints
    summary["layers"] = {str(k): v for k, v in summary["layers"].items()}
    (out / "summary.json").write_text(json.dumps(summary, indent=1,
                                                 default=str) + "\n")
    for layer, rec in summary["layers"].items():
        print(f"layer {layer}: mmcs={rec['mmcs']}")
    if args.gsp:
        g = summary["gsp"]
        print(f"gsp: n_projected={g['n_projected']} feasible={g['feasible']} "
              f"mean_col_sparsity={g['mean_col_sparsity']:.1f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
