"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 --mesh 1x1 --ckpt /tmp/run1

On a real TPU slice run without --smoke and with the pod mesh (e.g.
--mesh 16x16). The launcher owns: mesh construction, sharded state init (or
elastic restore from the latest checkpoint), the data pipeline, async
checkpointing, straggler monitoring hooks, and the projection constraint.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import models
from repro.configs import registry
from repro.configs.types import ProjectionSpec, TrainConfig
from repro.data import DataConfig, DataPipeline
from repro.models import params as PM
from repro.obs import jax_bridge
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.parallel import sharding as SH
from repro.runtime import CheckpointManager, StragglerMonitor
from repro.training import init_state, make_train_step
from repro.optim.projection_hook import tree_sparsity


def parse_mesh(spec: str):
    dims = [int(x) for x in spec.split("x")]
    if len(dims) == 2:
        return jax.make_mesh(tuple(dims), ("data", "model"))
    return jax.make_mesh(tuple(dims), ("pod", "data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--radius", type=float, default=0.0,
                    help=">0 enables the bi-level l1,inf constraint")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run here "
                         "(schedule stages show up as proj/* named scopes)")
    ap.add_argument("--telemetry-every", type=int, default=0,
                    help=">0 enables the host-callback telemetry bridge and "
                         "ships loss/grad-norm/sparsity/feasibility every "
                         "that many steps")
    ap.add_argument("--telemetry-marks", action="store_true",
                    help="also bracket the optimizer/projection epilogue "
                         "with ordered timing marks (costly: serializes a "
                         "host callback pair into every step)")
    ap.add_argument("--metrics-out", default="",
                    help="write the final obs-registry snapshot (JSON lines) "
                         "to this path")
    args = ap.parse_args()
    if args.telemetry_every > 0 or args.telemetry_marks:
        jax_bridge.enable()

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.get_arch(args.arch))
    api = models.get(cfg)
    mesh = parse_mesh(args.mesh)
    micro = args.microbatch or args.batch
    proj = None
    if args.radius > 0:
        proj = ProjectionSpec(pattern=r"(w_up|w_gate)", radius=args.radius)
    tcfg = TrainConfig(microbatch=micro, lr=args.lr, total_steps=args.steps,
                       warmup=min(20, args.steps // 5 + 1), remat=not args.smoke,
                       master_dtype="", projection=proj,
                       checkpoint_every=args.ckpt_every)

    pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                                   global_batch=args.batch, microbatch=micro))
    rules = SH.param_rules(mesh)
    specs = PM.param_specs(api.template(cfg), rules, SH.mesh_shape_dict(mesh))
    mgr = CheckpointManager(args.ckpt, keep=3) if args.ckpt else None
    mon = StragglerMonitor(n_hosts=jax.process_count())

    state, start = None, 0
    if mgr:
        state, manifest = mgr.restore(shardings=None)
        if state is not None:
            start = manifest["step"]
            print(f"[elastic restart] resuming from step {start}")
    if state is None:
        state = init_state(cfg, tcfg, api, jax.random.PRNGKey(tcfg.seed))
    step_hist = obs_metrics.get_registry().histogram(
        "train_step_seconds", "end-to-end wall time of one training step")
    with mesh, obs_profile.capture(args.profile_dir):
        state = {"params": jax.device_put(state["params"],
                                          SH.named(mesh, specs)),
                 "opt": state["opt"]}
        b_ax = SH.batch_axes(mesh)
        act_spec = P(b_ax if len(b_ax) > 1 else b_ax[0], None, None)
        step_fn = jax.jit(make_train_step(
            cfg, tcfg, api, impl="naive" if args.smoke else "chunked",
            n_groups=SH.dp_shards(mesh), act_spec=act_spec,
            mesh=mesh, param_specs=specs,
            telemetry_every=args.telemetry_every,
            telemetry_marks=args.telemetry_marks))

        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = {"tokens": jnp.asarray(pipe.batch(step))}
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step_hist.observe(dt)
            rep = mon.record({jax.process_index(): dt})
            if mgr and (step + 1) % tcfg.checkpoint_every == 0:
                mgr.save_async(step + 1, state)
            if (step + 1) % 10 == 0 or step + 1 == args.steps:
                msg = (f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                       f"gnorm {float(metrics['grad_norm']):.2f}")
                if rep.action != "none":
                    msg += f"  [straggler watch: {rep.stragglers}]"
                print(msg)
    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()
    if proj:
        for name, sp in tree_sparsity(state["params"], proj).items():
            print(f"column sparsity {name}: {float(sp):.1f}%")
    if args.metrics_out:
        obs_metrics.get_registry().write_jsonl(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.profile_dir:
        print(f"profiler trace -> {args.profile_dir} "
              f"({len(obs_profile.trace_files(args.profile_dir))} files)")


if __name__ == "__main__":
    main()
