"""Everything the dry-run needs per (arch × shape × mesh): abstract state,
input ShapeDtypeStructs, shardings, and the step function to lower.

``input_specs(cfg, shape, mesh, tuning)`` follows the assignment contract:
weak-type-correct ShapeDtypeStruct stand-ins, shardable, no allocation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.types import ArchConfig, ProjectionSpec, ShapeConfig, TrainConfig
from repro.models import params as PM
from repro.optim import adamw
from repro.parallel import sharding as SH
from repro.training import step as TS


# --------------------------------------------------- per-arch training tuning
@dataclasses.dataclass(frozen=True)
class Tuning:
    param_dtype: str = "bfloat16"
    master_dtype: str = "float32"
    moment_dtype: str = "float32"
    grad_allreduce_dtype: str = ""
    microbatch: int = 32          # global microbatch size for train_4k
    fsdp: bool = True
    attn_impl: str = "chunked"
    projection_pattern: str = r"(w_up|w_gate)"
    # ---- §Perf hillclimb knobs ----
    ep_2d: bool = False           # experts sharded over (data, model) — no FSDP
                                  # re-gather of expert weights per microbatch
    moe_dispatch: str = ""        # "" -> cfg default; "scatter" kills the
                                  # O(T²) one-hot dispatch einsum
    attn_chunk: int = 0           # 0 -> default 1024
    attn_probs_bf16: bool = False # store softmax probs bf16 (f32 accum)
    xlstm_chunk: int = 0          # mLSTM chunk length (state traffic ∝ 1/c)
    xlstm_shard_r: bool = False   # TP-shard sLSTM recurrent weights


TUNINGS: Dict[str, Tuning] = {
    # the trillion-scale MoEs: no fp32 master, int8 moments, bf16 grad accum
    "deepseek-v3-671b": Tuning(master_dtype="", moment_dtype="int8",
                               grad_allreduce_dtype="bfloat16", microbatch=16),
    "kimi-k2-1t-a32b": Tuning(master_dtype="", moment_dtype="int8",
                              grad_allreduce_dtype="bfloat16", microbatch=16),
    "qwen3-32b": Tuning(microbatch=16),
    "chameleon-34b": Tuning(microbatch=16),
}


def tuning_for(cfg: ArchConfig) -> Tuning:
    return TUNINGS.get(cfg.name, Tuning())


def apply_tuning(cfg: ArchConfig, tune: Tuning) -> ArchConfig:
    """Fold hillclimb knobs into the arch config + layers.ATTN_TUNE."""
    from repro.models import layers as L
    import jax.numpy as jnp
    L.ATTN_TUNE["chunk"] = tune.attn_chunk or 1024
    L.ATTN_TUNE["probs_dtype"] = jnp.bfloat16 if tune.attn_probs_bf16 else None
    if tune.moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=tune.moe_dispatch))
    if cfg.xlstm is not None and (tune.xlstm_chunk or tune.xlstm_shard_r):
        cfg = dataclasses.replace(
            cfg, xlstm=dataclasses.replace(
                cfg.xlstm, chunk=tune.xlstm_chunk or cfg.xlstm.chunk,
                shard_r=tune.xlstm_shard_r or cfg.xlstm.shard_r))
    return cfg


def train_config(cfg: ArchConfig, shape: ShapeConfig, tune: Tuning) -> TrainConfig:
    return TrainConfig(
        microbatch=tune.microbatch,
        param_dtype=tune.param_dtype,
        master_dtype=tune.master_dtype,
        moment_dtype=tune.moment_dtype,
        grad_allreduce_dtype=tune.grad_allreduce_dtype,
        remat=True,
        projection=ProjectionSpec(pattern=tune.projection_pattern,
                                  radius=100.0, every=1),
    )


# ------------------------------------------------------------ abstract state
def abstract_train_state(cfg: ArchConfig, tcfg: TrainConfig, api):
    """ShapeDtypeStruct tree matching training.init_state (no allocation)."""
    tpl = api.template(cfg)
    pdt = jnp.dtype(tcfg.param_dtype)
    params = PM.abstract_params(tpl, pdt)

    def mom(p):
        if tcfg.moment_dtype == "int8":
            npad = -(-p.shape[-1] // 256) * 256
            return {"q": jax.ShapeDtypeStruct(p.shape[:-1] + (npad,), jnp.int8),
                    "s": jax.ShapeDtypeStruct(p.shape[:-1] + (npad // 256,),
                                              jnp.float32)}
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(tcfg.moment_dtype))

    opt = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree_util.tree_map(mom, params),
        "v": jax.tree_util.tree_map(mom, params),
    }
    if tcfg.master_dtype and tcfg.master_dtype != tcfg.param_dtype:
        opt["master"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(tcfg.master_dtype)),
            params)
    return {"params": params, "opt": opt}


def state_shardings(cfg: ArchConfig, tcfg: TrainConfig, api, mesh, *,
                    fsdp: bool = True, ep_2d: bool = False):
    tpl = api.template(cfg)
    rules = SH.param_rules(mesh, fsdp=fsdp)
    if "pod" in mesh.axis_names and cfg.name.startswith(("kimi", "deepseek")):
        rules = dict(rules, embed=("pod", "data"))  # cross-pod ZeRO for the giants
    if ep_2d:
        rules = dict(rules, experts=("data", "model"))
    shp = SH.mesh_shape_dict(mesh)
    pspecs = PM.param_specs(tpl, rules, shp)
    ospecs = adamw.state_specs(pspecs, tpl, tcfg)
    specs = {"params": pspecs, "opt": ospecs}
    return SH.named(mesh, specs), specs


# ------------------------------------------------------------------ the cells
def train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, tune=None):
    """(step_fn, abstract_args, in_shardings, out_shardings, static info)."""
    tune = tune or tuning_for(cfg)
    cfg = apply_tuning(cfg, tune)
    tcfg = train_config(cfg, shape, tune)
    api = models.get(cfg)
    n_micro = shape.global_batch // tcfg.microbatch
    n_groups = SH.dp_shards(mesh)

    b_ax = SH.tokens_spec(mesh, shape, tcfg.microbatch)[1]
    act_spec = P(b_ax, None, None)
    v_ok = cfg.vocab % SH.mesh_shape_dict(mesh)["model"] == 0
    logits_spec = P(b_ax, None, "model" if v_ok else None)
    state = abstract_train_state(cfg, tcfg, api)
    batch = {"tokens": jax.ShapeDtypeStruct(
        (n_micro, tcfg.microbatch, shape.seq_len + 1), jnp.int32)}
    state_sh, state_specs_tree = state_shardings(cfg, tcfg, api, mesh,
                                                 fsdp=tune.fsdp,
                                                 ep_2d=tune.ep_2d)
    # the projection hook gets the params' PartitionSpecs so matched sharded
    # leaves run the schedule executor in place (no gather) — this is what the
    # hillclimb's roofline sees as the projection's collective cost
    step_fn = TS.make_train_step(cfg, tcfg, api, impl=tune.attn_impl,
                                 n_groups=n_groups, act_spec=act_spec,
                                 logits_spec=logits_spec, mesh=mesh,
                                 param_specs=state_specs_tree["params"])
    batch_sh = SH.named(mesh, {"tokens": SH.tokens_spec(mesh, shape,
                                                        tcfg.microbatch)})
    metrics_sh = SH.named(mesh, {"loss": P(), "grad_norm": P(), "lr": P()})
    return dict(
        fn=step_fn,
        args=(state, batch),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        tcfg=tcfg,
        donate=(0,),
    )


def decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """serve_step: one new token, cache of shape.seq_len."""
    from repro.serving import engine
    api = models.get(cfg)
    b = shape.global_batch
    n_groups = max(1, min(SH.dp_shards(mesh), b))
    step_fn = engine.make_decode_step(cfg, api, n_groups=n_groups)
    cache_ab = jax.eval_shape(
        lambda: api.make_cache(cfg, b, shape.seq_len, dtype=jnp.bfloat16))
    cache_specs = SH.cache_spec_tree(cfg, mesh, cache_ab, shape)

    tune = tuning_for(cfg)
    tpl = api.template(cfg)
    params_ab = PM.abstract_params(tpl, jnp.bfloat16)
    rules = SH.param_rules(mesh, fsdp=tune.fsdp)
    pspecs = PM.param_specs(tpl, rules, SH.mesh_shape_dict(mesh))

    tokens_ab = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_ab = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = SH.batch_spec(mesh, b, extra_dims=0)

    b_ax = tok_spec[0] if len(tok_spec) else None
    logits_spec = P(b_ax, "model" if cfg.vocab % SH.mesh_shape_dict(mesh)["model"] == 0 else None)
    in_sh = (SH.named(mesh, pspecs), SH.named(mesh, tok_spec),
             SH.named(mesh, cache_specs), SH.named(mesh, P()))
    out_sh = (SH.named(mesh, tok_spec), SH.named(mesh, logits_spec),
              SH.named(mesh, cache_specs))
    return dict(
        fn=step_fn,
        args=(params_ab, tokens_ab, cache_ab, pos_ab),
        in_shardings=in_sh,
        out_shardings=out_sh,
        tcfg=None,
        donate=(2,),
    )


def prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Full-sequence forward (logits at the last position)."""
    from repro.serving import engine
    api = models.get(cfg)
    tune = tuning_for(cfg)
    tok_spec0 = SH.batch_spec(mesh, shape.global_batch, extra_dims=1)
    act_spec = P(tok_spec0[0] if len(tok_spec0) else None, None, None)
    step_fn = engine.make_prefill(cfg, api, impl=tune.attn_impl,
                                  act_spec=act_spec)

    tpl = api.template(cfg)
    params_ab = PM.abstract_params(tpl, jnp.bfloat16)
    pspecs = PM.param_specs(tpl, SH.param_rules(mesh, fsdp=tune.fsdp),
                            SH.mesh_shape_dict(mesh))
    tokens_ab = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                     jnp.int32)
    tok_spec = SH.batch_spec(mesh, shape.global_batch, extra_dims=1)
    in_sh = (SH.named(mesh, pspecs), SH.named(mesh, tok_spec))
    b_ax = tok_spec[0] if len(tok_spec) else None
    v_ok = cfg.vocab % SH.mesh_shape_dict(mesh)["model"] == 0
    out_sh = SH.named(mesh, P(b_ax, "model" if v_ok else None))
    return dict(fn=step_fn, args=(params_ab, tokens_ab), in_shardings=in_sh,
                out_shardings=out_sh, tcfg=None, donate=())


def sae_factory_cell(d_model: int, mesh, *, expansion: int = 8,
                     batch: int = 4096, microbatch: int = 512,
                     radius: float = 1.0, heads: int = 1):
    """The factory's projected dictionary-SAE train step as a lowerable cell.

    Activation rows stream in (n_micro, mb, d_model); the encoder weight
    ((d_model, expansion*d_model), 'ffn'-sharded over 'model') is projected
    onto the bi-level ball every step — through the §3 mesh executor when its
    trailing axis is sharded, so the dry-run/roofline sees the factory's real
    collective cost at production batch sizes. ``heads > 1`` is the §6
    head-structured variant: a 3-D encoder (d_model, heads, d_dict//heads)
    projected onto the tri-level ℓ1,∞,∞ ball.
    """
    from repro.models import sae
    from repro.training import sae_factory as F

    d_dict = expansion * d_model
    fcfg = F.SAEFactoryConfig(expansion=expansion, radius=radius,
                              microbatch=microbatch, sae_batch=batch,
                              heads=heads)
    tcfg = F.sae_train_config(fcfg)
    tpl = sae.dict_template(d_model, d_dict, heads=heads)
    pspecs = PM.param_specs(tpl, SH.param_rules(mesh, fsdp=True),
                            SH.mesh_shape_dict(mesh))
    params = PM.abstract_params(tpl, jnp.dtype(tcfg.param_dtype))
    ospecs = adamw.state_specs(pspecs, tpl, tcfg)
    state = {"params": params, "opt": {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
        "v": jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
    }}
    n_micro = batch // microbatch
    b_ax = SH.batch_spec(mesh, microbatch, extra_dims=0)
    rows_spec = P(None, b_ax[0] if len(b_ax) else None, None)
    batch_ab = {"tokens": jax.ShapeDtypeStruct((n_micro, microbatch, d_model),
                                               jnp.float32)}
    step_fn = F.make_sae_train_step(tcfg, mesh=mesh, param_specs=pspecs)
    state_sh = SH.named(mesh, {"params": pspecs, "opt": ospecs})
    metrics_sh = SH.named(mesh, {"loss": P(), "grad_norm": P(), "lr": P()})
    return dict(
        fn=step_fn,
        args=(state, batch_ab),
        in_shardings=(state_sh, SH.named(mesh, {"tokens": rows_spec})),
        out_shardings=(state_sh, metrics_sh),
        tcfg=tcfg,
        donate=(0,),
    )


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, tune=None):
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh, tune=tune)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh)
    return decode_cell(cfg, shape, mesh)


# cells that are skipped by assignment rule (full attention at 500k)
FULL_ATTENTION_500K_SKIP = {
    "stablelm-1.6b", "granite-3-2b", "qwen3-32b", "whisper-large-v3",
    "deepseek-v3-671b", "kimi-k2-1t-a32b", "chameleon-34b",
}


def cell_skipped(cfg: ArchConfig, shape: ShapeConfig):
    if shape.name == "long_500k" and cfg.name in FULL_ATTENTION_500K_SKIP:
        return ("skip: pure full-attention arch at 524k decode "
                "(sub-quadratic required; see DESIGN.md §5)")
    return None
