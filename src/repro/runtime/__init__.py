"""repro.runtime — checkpointing, fault tolerance, double descent."""
from .checkpoint import CheckpointManager  # noqa: F401
from .double_descent import double_descent  # noqa: F401
from .resilience import (  # noqa: F401
    HeartbeatFile, StragglerMonitor, StragglerReport, run_with_restarts,
)
