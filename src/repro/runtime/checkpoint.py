"""Fault-tolerant checkpointing: atomic, async, keep-K, elastic restore.

Layout:  <dir>/step_<N>/{manifest.json, arrays.npz}  (+ .tmp staging)

* atomic   — written to ``step_N.tmp`` then os.rename'd (a crash mid-save can
             never corrupt the latest valid checkpoint).
* async    — ``save_async`` snapshots to host memory synchronously (cheap)
             and writes on a daemon thread; ``wait()`` joins before exit.
* keep-K   — oldest checkpoints garbage-collected after each successful save.
* elastic  — arrays are saved *unsharded* (gathered); ``restore`` re-shards
             onto whatever mesh/sharding the new job passes in, so the data
             axis can shrink/grow between runs (elastic scaling).
* stream   — the data cursor is the step (see data/pipeline.py), and the RNG
             seed lives in the manifest: restart is bit-exact.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: Optional[dict] = None):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        self._write(step, host, extra or {})

    def save_async(self, step: int, state, extra: Optional[dict] = None):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), state)  # snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: dict):
        flat = _flatten(host_state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "╱"): v for k, v in flat.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(flat),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; ``shardings`` (same tree structure, NamedSharding
        leaves) re-shards onto the *current* mesh — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(path, "arrays.npz"))
        flat = {k.replace("╱", "/"): z[k] for k in z.files}
        tree = _unflatten(flat)

        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else jnp.asarray(v)
                for k, v in flat.items()})
        else:
            tree = jax.tree_util.tree_map(jnp.asarray, tree)
        return tree, manifest
