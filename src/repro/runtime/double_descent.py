"""Double-descent training schedule (paper Appendix B, Algorithm 8).

descent #1: train N epochs → project (BP^{p,q}) → extract the zero mask →
rewind surviving weights to their INITIAL values → descent #2: retrain with
the mask frozen (grads and weights multiplied by the mask every step).
This is the lottery-ticket-style schedule the paper uses for its SAE tables.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.types import ProjectionSpec
from repro.core import masks as M
from repro.optim.projection_hook import project_tree


def double_descent(init_params, train_epochs_fn: Callable, spec: ProjectionSpec,
                   projector: Callable = None, rewind: bool = True):
    """Run the two descents (paper Alg 8: project ONCE after descent #1).

    ``train_epochs_fn(params, mask_or_None) -> trained_params`` encapsulates
    one full descent (the caller owns optimizer/loop). ``projector`` overrides
    the mask-inducing projection (e.g. the exact ℓ1,∞ baseline).
    ``rewind=False`` is the fine-tuning ablation: descent #2 continues from
    the PROJECTED weights instead of masked initialization (no lottery-ticket
    rewind — the SAE factory sweep reports both). Returns
    (final_params, mask_tree, sparsity_per_leaf).
    """
    # descent 1 — unconstrained
    trained = train_epochs_fn(init_params, None)
    # project onto the ball, then freeze the induced structured mask
    projected = projector(trained) if projector is not None \
        else project_tree(trained, spec)
    mask = jax.tree_util.tree_map(
        lambda p: (jnp.abs(p) > 0).astype(p.dtype), projected)
    # rewind: surviving weights restart from initialization (masked);
    # no-rewind: keep the projected weights and fine-tune under the mask
    start = init_params if rewind else projected
    rewound = jax.tree_util.tree_map(lambda w0, m: w0 * m, start, mask)
    # descent 2 — masked retrain
    final = train_epochs_fn(rewound, mask)
    stats = {}

    def _collect(path, p):
        name = "/".join(str(getattr(q, "key", q)) for q in path)
        if p.ndim >= 2:
            stats[name] = float(M.sparsity(p.reshape(-1, p.shape[-1]), axis=0))
        return p

    jax.tree_util.tree_map_with_path(_collect, final)
    return final, mask, stats
