"""Fault-tolerance runtime: restart driver, straggler monitor, heartbeats.

At 1000+ nodes the failure model is: a host dies (checkpoint-restart), a host
slows down (straggler mitigation), or the allocation changes size (elastic).
This module provides the coordinator-side logic; it is exercised in tests via
simulated timings and a SIGKILL'd subprocess (tests/test_runtime.py).

* ``StragglerMonitor`` — per-host step-time EWMA + deviation watchdog; flags
  hosts whose step time exceeds ``threshold × p50``. On TPU pods, the
  recommended action (returned, not enforced) is "checkpoint + evict + remesh"
  since SPMD steps are barrier-synchronized and one slow host gates the fleet.
* ``HeartbeatFile`` — cheap cross-process liveness protocol (mtime-based),
  standing in for the cluster manager's health service.
* ``run_with_restarts`` — supervises a train function: on crash, restores the
  latest checkpoint and continues; gives up after ``max_restarts``.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    step: int
    p50: float
    worst_host: int
    worst_time: float
    stragglers: List[int]
    action: str  # "none" | "warn" | "evict"


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 32,
                 warn_factor: float = 1.5, evict_factor: float = 3.0,
                 min_samples: int = 8):
        self.n_hosts = n_hosts
        self.window = window
        self.warn_factor = warn_factor
        self.evict_factor = evict_factor
        self.min_samples = min_samples
        self.history: Dict[int, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self.step = 0

    def record(self, host_times: Dict[int, float]) -> StragglerReport:
        """host -> seconds for this step. Returns the verdict."""
        self.step += 1
        for h, t in host_times.items():
            self.history[h].append(t)
        means = {h: float(np.mean(v)) for h, v in self.history.items()
                 if len(v) >= min(self.min_samples, self.step)}
        if not means:
            return StragglerReport(self.step, 0.0, -1, 0.0, [], "none")
        p50 = float(np.median(list(means.values())))
        worst = max(means, key=means.get)
        stragglers = [h for h, m in means.items()
                      if m > self.warn_factor * p50]
        action = "none"
        if stragglers:
            action = "warn"
        if any(means[h] > self.evict_factor * p50 for h in stragglers):
            action = "evict"
        return StragglerReport(self.step, p50, worst, means[worst],
                               sorted(stragglers), action)


class HeartbeatFile:
    """mtime-based liveness: hosts touch ``<dir>/host_<id>``; the coordinator
    reports hosts whose heartbeat is older than ``timeout`` seconds."""

    def __init__(self, directory: str, timeout: float = 60.0):
        self.dir = directory
        self.timeout = timeout
        os.makedirs(directory, exist_ok=True)

    def beat(self, host_id: int):
        path = os.path.join(self.dir, f"host_{host_id}")
        with open(path, "a"):
            os.utime(path, None)

    def dead_hosts(self, expected: int, now: Optional[float] = None) -> List[int]:
        now = now or time.time()
        dead = []
        for h in range(expected):
            path = os.path.join(self.dir, f"host_{h}")
            if not os.path.exists(path) or now - os.path.getmtime(path) > self.timeout:
                dead.append(h)
        return dead


def run_with_restarts(train_fn: Callable[[Optional[int]], int],
                      ckpt_mgr, max_restarts: int = 3) -> int:
    """``train_fn(resume_step) -> final_step``; re-invoked from the latest
    checkpoint on any exception. Returns the final step reached."""
    restarts = 0
    while True:
        resume = ckpt_mgr.latest_step()
        try:
            return train_fn(resume)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
