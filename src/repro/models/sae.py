"""Supervised AutoEncoder of the paper (§7.3): the original application of the
bi-level projection.

Encoder d → h → k (latent dim == #classes, used directly as logits);
symmetric decoder k → h → d. Loss = α·Huber(x, x̂) + CE(y, z), trained under
the hard constraint ‖W‖ ≤ η enforced by projection (double descent lives in
runtime/double_descent.py). SiLU or ReLU activation per the paper's tables.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from .params import ParamDef


def template(cfg: ArchConfig):
    d, h, k = cfg.d_model, cfg.d_ff, cfg.vocab  # vocab doubles as n_classes
    return {
        "enc1": {"w": ParamDef((d, h), ("embed", "ffn"), "scaled"),
                 "b": ParamDef((h,), (None,), "zeros")},
        "enc2": {"w": ParamDef((h, k), ("ffn", None), "scaled"),
                 "b": ParamDef((k,), (None,), "zeros")},
        "dec1": {"w": ParamDef((k, h), (None, "ffn"), "scaled"),
                 "b": ParamDef((h,), (None,), "zeros")},
        "dec2": {"w": ParamDef((h, d), ("ffn", "embed"), "scaled"),
                 "b": ParamDef((d,), (None,), "zeros")},
    }


def _act(x, kind):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.relu(x)


def forward(params, x, cfg: ArchConfig, *, act: str = "silu", **_):
    """x (B, d) -> (latent logits (B, k), reconstruction (B, d))."""
    h = _act(x @ params["enc1"]["w"] + params["enc1"]["b"], act)
    z = h @ params["enc2"]["w"] + params["enc2"]["b"]
    h2 = _act(z @ params["dec1"]["w"] + params["dec1"]["b"], act)
    xr = h2 @ params["dec2"]["w"] + params["dec2"]["b"]
    return z, xr


def huber(x, y, delta: float = 1.0):
    r = jnp.abs(x - y)
    return jnp.mean(jnp.where(r < delta, 0.5 * r * r, delta * (r - 0.5 * delta)))


def loss_fn(params, batch, cfg: ArchConfig, *, alpha: float = 1.0,
            act: str = "silu"):
    """Paper eq. (18): α·ψ(X, X̂) + H(Y, Z)."""
    x, y = batch["x"], batch["y"]
    z, xr = forward(params, x, cfg, act=act)
    rec = huber(x, xr)
    logp = jax.nn.log_softmax(z.astype(jnp.float32))
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return alpha * rec + ce, {"rec": rec, "ce": ce}
