"""Supervised AutoEncoder of the paper (§7.3): the original application of the
bi-level projection.

Encoder d → h → k (latent dim == #classes, used directly as logits);
symmetric decoder k → h → d. Loss = α·Huber(x, x̂) + CE(y, z), trained under
the hard constraint ‖W‖ ≤ η enforced by projection (double descent lives in
runtime/double_descent.py). SiLU or ReLU activation per the paper's tables.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from .params import ParamDef


def template(cfg: ArchConfig):
    d, h, k = cfg.d_model, cfg.d_ff, cfg.vocab  # vocab doubles as n_classes
    return {
        "enc1": {"w": ParamDef((d, h), ("embed", "ffn"), "scaled"),
                 "b": ParamDef((h,), (None,), "zeros")},
        "enc2": {"w": ParamDef((h, k), ("ffn", None), "scaled"),
                 "b": ParamDef((k,), (None,), "zeros")},
        "dec1": {"w": ParamDef((k, h), (None, "ffn"), "scaled"),
                 "b": ParamDef((h,), (None,), "zeros")},
        "dec2": {"w": ParamDef((h, d), ("ffn", "embed"), "scaled"),
                 "b": ParamDef((d,), (None,), "zeros")},
    }


def _act(x, kind):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.relu(x)


def forward(params, x, cfg: ArchConfig, *, act: str = "silu", **_):
    """x (B, d) -> (latent logits (B, k), reconstruction (B, d))."""
    h = _act(x @ params["enc1"]["w"] + params["enc1"]["b"], act)
    z = h @ params["enc2"]["w"] + params["enc2"]["b"]
    h2 = _act(z @ params["dec1"]["w"] + params["dec1"]["b"], act)
    xr = h2 @ params["dec2"]["w"] + params["dec2"]["b"]
    return z, xr


def huber(x, y, delta: float = 1.0):
    r = jnp.abs(x - y)
    return jnp.mean(jnp.where(r < delta, 0.5 * r * r, delta * (r - 0.5 * delta)))


def loss_fn(params, batch, cfg: ArchConfig, *, alpha: float = 1.0,
            act: str = "silu"):
    """Paper eq. (18): α·ψ(X, X̂) + H(Y, Z)."""
    x, y = batch["x"], batch["y"]
    z, xr = forward(params, x, cfg, act=act)
    rec = huber(x, xr)
    logp = jax.nn.log_softmax(z.astype(jnp.float32))
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return alpha * rec + ce, {"rec": rec, "ce": ce}


# ------------------------------------------------------- activation-dictionary
# The factory's SAE (training/sae_factory.py): a one-hidden-layer dictionary
# autoencoder trained on harvested LM activations (data/activations.py).
# Unlike the L1-penalty SAEs of the interpretability literature, sparsity here
# is the paper's HARD constraint: the encoder weight is projected onto the
# l1,inf (or tri-level) ball every optimizer step, zeroing whole feature
# columns. The decoder weight is the learned dictionary compared across runs
# with MMCS (training/mmcs.py).

def dict_template(d_in: int, d_dict: int, heads: int = 1):
    """Params for the activation SAE: encode d_in -> d_dict, decode back.

    ``heads > 1`` is the HEAD-STRUCTURED variant (paper §6): the dictionary
    splits into ``heads`` feature groups and the encoder/decoder weights keep
    the head axis explicit — ``enc/w`` is (d_in, heads, d_dict//heads) — so a
    tri-level ν can aggregate per head (zeroing whole heads, not just whole
    features). The forward math is identical: the head axes flatten back to
    d_dict inside :func:`dict_forward`.
    """
    if d_dict % heads:
        raise ValueError(f"d_dict={d_dict} not divisible by heads={heads}")
    if heads == 1:
        enc_w = ParamDef((d_in, d_dict), ("embed", "ffn"), "scaled")
        dec_w = ParamDef((d_dict, d_in), ("ffn", "embed"), "scaled")
    else:
        enc_w = ParamDef((d_in, heads, d_dict // heads),
                         ("embed", None, "ffn"), "scaled")
        dec_w = ParamDef((heads, d_dict // heads, d_in),
                         (None, "ffn", "embed"), "scaled")
    return {
        "enc": {"w": enc_w,
                "b": ParamDef((d_dict,), (None,), "zeros")},
        "dec": {"w": dec_w,
                "b": ParamDef((d_in,), (None,), "zeros")},
    }


def dict_forward(params, x):
    """x (B, d_in) -> (features (B, d_dict), reconstruction (B, d_in)).

    Pre-bias form (x is decoder-bias-centred before encoding), ReLU features.
    Head-structured weights (3-D, from ``dict_template(heads>1)``) flatten to
    the same (d_in, d_dict) / (d_dict, d_in) matmuls.
    """
    we, wd = params["enc"]["w"], params["dec"]["w"]
    we = we.reshape(we.shape[0], -1)
    wd = wd.reshape(-1, wd.shape[-1])
    xc = x - params["dec"]["b"]
    f = jax.nn.relu(xc @ we + params["enc"]["b"])
    xr = f @ wd + params["dec"]["b"]
    return f, xr


def dict_loss(params, x, *, l1: float = 0.0):
    """Scalar reconstruction loss (+ optional L1 on features, default OFF —
    the paper's projection constraint replaces the penalty)."""
    f, xr = dict_forward(params, x)
    mse = jnp.mean(jnp.square(x - xr))
    if l1:
        mse = mse + l1 * jnp.mean(jnp.abs(f))
    return mse


def dict_metrics(params, x):
    """Diagnostics: reconstruction MSE, mean feature L0, fraction dead."""
    f, xr = dict_forward(params, x)
    active = (f > 0).astype(jnp.float32)
    return {
        "mse": jnp.mean(jnp.square(x - xr)),
        "l0": jnp.mean(jnp.sum(active, axis=-1)),
        "dead_frac": jnp.mean((jnp.max(active, axis=0) == 0).astype(jnp.float32)),
    }
