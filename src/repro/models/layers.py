"""Neural-net building blocks shared by all 10 assigned architectures.

Everything is a pure function of (params, inputs). Attention comes in three
implementations selected by ``impl``:

  * "naive"   — materializes S×S logits (tiny smoke tests only)
  * "chunked" — lax.scan online softmax over KV chunks: memory-bounded, pure
                jnp, the dry-run/default path (flash semantics, XLA-lowered)
  * "pallas"  — repro.kernels flash kernel (real TPUs)

All attention math accumulates in f32 regardless of compute dtype.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.types import MLAConfig, MoEConfig, SSMConfig
from .params import ParamDef

_NEG = -1e30

# hillclimb knobs for the chunked attention path (set by launch/specs.py
# before lowering; trace-time constants, see EXPERIMENTS.md §Perf)
ATTN_TUNE = {"chunk": 1024, "probs_dtype": None}  # None -> f32 probs


# ---------------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, rope_pct: float, theta: float, positions):
    """positions (…,) int32 -> (cos, sin) of shape (…, rot_dim//2)."""
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    if rot == 0:
        return None
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, freqs):
    """x (..., S, H, D); freqs from rope_frequencies with positions (..., S)."""
    if freqs is None:
        return x
    cos, sin, rot = freqs
    xf = x.astype(jnp.float32)
    xr, xp = xf[..., :rot], xf[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ attention
def _gqa_logits(q, k):
    """q (B,S,KV,G,D) × k (B,T,KV,D) -> (B,KV,G,S,T) in f32."""
    return jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def attention_naive(q, k, v, *, causal=True, window=None, q_offset=0):
    """q (B,S,H,Dqk), k (B,T,KV,Dqk), v (B,T,KV,Dv). Returns (B,S,H,Dv)."""
    b, s, h, d = q.shape
    t, kv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d) * (d ** -0.5)
    logits = _gqa_logits(qg, k)
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, dv).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=None, q_offset=0,
                      chunk=1024):
    """Online-softmax over KV chunks (flash semantics in pure jnp)."""
    b, s, h, d = q.shape
    t, kv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kv
    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    t_pad = n_chunks * chunk
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(b, n_chunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, dv).transpose(1, 0, 2, 3, 4)

    qg = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, s, kv, g, d)
    qpos = (jnp.arange(s) + q_offset)[:, None]

    m0 = jnp.full((b, kv, g, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    a0 = jnp.zeros((b, kv, g, s, dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        logits = _gqa_logits(qg, kb)  # (b,kv,g,s,chunk)
        kpos = (idx * chunk + jnp.arange(chunk))[None, :]
        mask = kpos < t
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pd = ATTN_TUNE.get("probs_dtype")
        pv = p.astype(pd) if pd is not None else p
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", pv, vb.astype(pd or jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]          # (b,kv,g,s,dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, q_offset=0,
              impl="chunked", chunk=None):
    if impl == "naive":
        return attention_naive(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset,
                                 chunk=chunk or ATTN_TUNE["chunk"])
    if impl == "pallas":
        from repro.kernels import ops
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = ops.attention(qt, kt, vt, causal=causal, window=window)
        return o.transpose(0, 2, 1, 3)
    raise ValueError(f"unknown attention impl {impl!r}")


def attention_decode(q, k_cache, v_cache, cur_len, *, window=None):
    """Single-token decode. q (B,H,D); caches (B,T,KV,D); cur_len int32.

    Pure reductions over the cache axis — GSPMD keeps the cache sharded over
    'model' (sequence dim) and inserts partial-softmax all-reduces
    (flash-decode). ``window`` caches are ring buffers: every slot is valid
    once the ring wraps, and positions are handled by the caller.
    """
    b, h, d = q.shape
    t, kv, dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[-1]
    g = h // kv
    qg = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(t)[None, :]
    if window is None:
        valid = pos < cur_len[:, None]                    # (B, T)
    else:
        valid = pos < jnp.minimum(cur_len, t)[:, None]    # ring: all once full
    logits = jnp.where(valid[:, None, None], logits, _NEG)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p / l, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dv).astype(q.dtype)


# ------------------------------------------------------------------------ mlp
def _act(x, kind):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


def mlp_template(d_model: int, d_ff: int, act: str = "silu"):
    t = {
        "w_up": ParamDef((d_model, d_ff), ("embed", "ffn"), "scaled"),
        "w_down": ParamDef((d_ff, d_model), ("ffn", "embed"), "scaled"),
    }
    if act != "gelu":  # gated (SwiGLU-style) for silu/relu families
        t["w_gate"] = ParamDef((d_model, d_ff), ("embed", "ffn"), "scaled")
    return t


def mlp_apply(p, x, act="silu"):
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = _act(x @ p["w_gate"], act) * up
    else:
        up = _act(up, act)
    return up @ p["w_down"]


# ------------------------------------------------------------------------ moe
def moe_template(d_model: int, cfg: MoEConfig):
    e, f = cfg.n_experts, cfg.d_expert
    t = {
        "router": ParamDef((d_model, e), ("embed", None), "scaled"),
        "w_gate": ParamDef((e, d_model, f), ("experts", "embed", "expert_ff"), "scaled"),
        "w_up": ParamDef((e, d_model, f), ("experts", "embed", "expert_ff"), "scaled"),
        "w_down": ParamDef((e, f, d_model), ("experts", "expert_ff", "embed"), "scaled"),
    }
    if cfg.n_shared:
        ds = cfg.d_shared or cfg.d_expert
        t["shared"] = mlp_template(d_model, ds * cfg.n_shared, "silu")
    return t


def moe_apply(p, x, cfg: MoEConfig, *, n_groups: int, act="silu"):
    """GShard-style capacity-dispatch MoE. x (T, M) flattened tokens.

    Tokens are split into ``n_groups`` groups (≈ one per data shard); dispatch
    is per-group so the position-cumsum never crosses shards. ``einsum``
    dispatch is the robust GSPMD path; ``scatter`` (cfg.dispatch) is the
    gather-based variant used by the §Perf hillclimb.
    """
    tkns, m = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = math.gcd(n_groups, tkns)
    tg = tkns // g
    cap = int(max(1, math.ceil(tg * k / e * cfg.capacity_factor)))
    xg = x.reshape(g, tg, m)

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (g, tg, e)
    top_v, top_i = jax.lax.top_k(probs, k)                     # (g, tg, k)
    top_v = top_v / jnp.sum(top_v, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)       # (g, tg, k, e)
    slot_mask = onehot                                         # k slots in priority order
    # position of each (token, slot) in its expert queue
    flat = slot_mask.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (g, tg*k, e)
    pos = jnp.sum(pos.reshape(g, tg, k, e) * onehot, axis=-1)  # (g, tg, k)
    expert_of = top_i
    keep = pos < cap
    gate = top_v * keep

    if cfg.dispatch == "einsum":
        # collapse the k slots: a token holds at most one slot per expert
        oh_e = jax.nn.one_hot(expert_of, e, dtype=jnp.float32)   # (g, tg, k, e)
        mask_te = jnp.einsum("gtke,gtk->gte", oh_e, keep.astype(jnp.float32))
        pos_te = jnp.einsum("gtke,gtk->gte", oh_e, pos)
        gate_te = jnp.einsum("gtke,gtk->gte", oh_e, gate)
        oh_c = jax.nn.one_hot(pos_te.astype(jnp.int32), cap, dtype=jnp.float32)
        disp_te = (mask_te[..., None] * oh_c).astype(x.dtype)   # (g, tg, e, cap)
        xe = jnp.einsum("gtec,gtm->gecm", disp_te, xg)          # (g, e, cap, m)
        h = jnp.einsum("gecm,emf->gecf", xe, p["w_up"])
        hg = _act(jnp.einsum("gecm,emf->gecf", xe, p["w_gate"]), act)
        ye = jnp.einsum("gecf,efm->gecm", h * hg, p["w_down"])
        comb = (gate_te[..., None].astype(x.dtype) * disp_te)   # (g, tg, e, cap)
        out = jnp.einsum("gtec,gecm->gtm", comb, ye)
    else:  # scatter: gather-based dispatch (no one-hot matmul FLOPs)
        slot_idx = (expert_of * cap + pos.astype(jnp.int32))   # (g, tg, k)
        slot_idx = jnp.where(keep, slot_idx, e * cap)          # overflow -> dropped row
        buf = jnp.zeros((g, e * cap + 1, m), x.dtype)
        src = jnp.repeat(xg[:, :, None, :], k, axis=2)         # (g, tg, k, m)
        buf = buf.at[jnp.arange(g)[:, None, None],
                     slot_idx, :].add(src, mode="drop")
        xe = buf[:, : e * cap, :].reshape(g, e, cap, m)
        h = jnp.einsum("gecm,emf->gecf", xe, p["w_up"])
        hg = _act(jnp.einsum("gecm,emf->gecf", xe, p["w_gate"]), act)
        ye = jnp.einsum("gecf,efm->gecm", h * hg, p["w_down"]).reshape(g, e * cap, m)
        ye = jnp.concatenate([ye, jnp.zeros((g, 1, m), x.dtype)], axis=1)
        gath = ye[jnp.arange(g)[:, None, None], slot_idx, :]   # (g, tg, k, m)
        out = jnp.sum(gath * gate[..., None].astype(x.dtype), axis=2)

    if cfg.n_shared:
        out = out + mlp_apply(p["shared"], xg, act)
    # aux load-balance loss (Switch): mean fraction * mean prob per expert
    me = jnp.mean(jnp.sum(onehot, axis=2), axis=1)             # (g, e) token frac
    pe = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(me * pe, axis=-1))
    return out.reshape(tkns, m), aux


# --------------------------------------------------------------------- mamba2
def mamba2_template(d_model: int, cfg: SSMConfig):
    di = cfg.expand * d_model
    h = di // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    return {
        # fused input projection: [z(di), x(di), B(gn), C(gn), dt(h)]
        "w_in": ParamDef((d_model, 2 * di + 2 * gn + h), ("embed", "ssm_in"), "scaled"),
        "conv_w": ParamDef((cfg.d_conv, di + 2 * gn), (None, None), "scaled", 0.1),
        "a_log": ParamDef((h,), (None,), "zeros"),
        "d_skip": ParamDef((h,), (None,), "ones"),
        "dt_bias": ParamDef((h,), (None,), "zeros"),
        "norm": ParamDef((di,), (None,), "ones"),
        "w_out": ParamDef((di, d_model), ("ssm_in", "embed"), "scaled"),
    }


def _ssd_chunked(x, dt, A, B, C, *, chunk: int):
    """Mamba2 SSD, chunked-parallel. x (b,s,h,p), dt (b,s,h), A (h,),
    B/C (b,s,g,n) with h % g == 0. Returns (b,s,h,p). f32 internally."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # chunked views (b, nc, c, ...)
    xc = x.reshape(b, nc, c, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, c, h).astype(jnp.float32)
    Bc = jnp.repeat(B.reshape(b, nc, c, g, n), rep, axis=3).astype(jnp.float32)
    Cc = jnp.repeat(C.reshape(b, nc, c, g, n), rep, axis=3).astype(jnp.float32)

    dA = dtc * (-jnp.exp(A.astype(jnp.float32)))[None, None, None, :]  # ≤ 0
    cum = jnp.cumsum(dA, axis=2)                                # (b,nc,c,h)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,nc,i,j,h)
    ii = jnp.arange(c)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(li), 0.0)
    scores = jnp.einsum("bzihn,bzjhn->bzijh", Cc, Bc) * L
    y_diag = jnp.einsum("bzijh,bzjhp->bzihp", scores, xc * dtc[..., None])
    # chunk end-states: S_z = sum_j exp(cum_end - cum_j) * B_j x_j dt_j
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)                # (b,nc,c,h)
    S = jnp.einsum("bzjhn,bzjhp->bzhnp",
                   Bc * (decay_out * dtc)[..., None], xc)
    # cross-chunk recurrence over z
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (b,nc,h)

    def scan_fn(hprev, inp):
        Sz, dz = inp
        hnew = hprev * dz[..., None, None] + Sz
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, h_in = jax.lax.scan(scan_fn, h0,
                           (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                        # (b,nc,h,n,p) state entering chunk
    y_off = jnp.einsum("bzihn,bzhnp->bzihp", Cc * jnp.exp(cum)[..., None], h_in)
    y = (y_diag + y_off).reshape(b, nc * c, h, p)
    return y[:, :s]


def mamba2_apply(p, x, cfg: SSMConfig, *, state=None):
    """Mamba2 block. x (b, s, d). If ``state`` is given (decode), s must be 1
    and the returned aux is the updated (conv_state, ssm_state)."""
    b, s, d = x.shape
    di = cfg.expand * d
    h = di // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    proj = x @ p["w_in"]
    z, xs, Bf, Cf, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    conv_in = jnp.concatenate([xs, Bf, Cf], axis=-1)            # (b, s, di+2gn)
    if state is None:
        # causal depthwise conv over time
        ci = jnp.pad(conv_in, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
        win = jnp.stack([ci[:, i:i + s] for i in range(cfg.d_conv)], axis=-1)
        conv = jnp.einsum("bsdk,kd->bsd", win, p["conv_w"])
        conv_state_new = None
    else:
        conv_state, ssm_state = state
        roll = jnp.concatenate([conv_state[:, 1:], conv_in], axis=1)
        conv = jnp.einsum("bkd,kd->bd", roll, p["conv_w"])[:, None, :]
        conv_state_new = roll
    conv = jax.nn.silu(conv)
    xs2, Bf2, Cf2 = jnp.split(conv, [di, di + gn], axis=-1)
    xh = xs2.reshape(b, s, h, cfg.head_dim)
    Bm = Bf2.reshape(b, s, cfg.n_groups, cfg.d_state)
    Cm = Cf2.reshape(b, s, cfg.n_groups, cfg.d_state)

    if state is None:
        y = _ssd_chunked(xh, dt, p["a_log"], Bm, Cm, chunk=cfg.chunk)
        new_state = None
    else:
        # single-step recurrence: h' = h * exp(dt·A) + dt·B⊗x ; y = C·h'
        rep = h // cfg.n_groups
        dA = jnp.exp(dt[:, 0] * (-jnp.exp(p["a_log"].astype(jnp.float32))))  # (b,h)
        Br = jnp.repeat(Bm[:, 0], rep, axis=1).astype(jnp.float32)           # (b,h,n)
        Cr = jnp.repeat(Cm[:, 0], rep, axis=1).astype(jnp.float32)
        xf = xh[:, 0].astype(jnp.float32)                                    # (b,h,p)
        upd = (dt[:, 0, :, None, None] * Br[..., None]) * xf[:, :, None, :]
        hnew = ssm_state * dA[..., None, None] + upd                         # (b,h,n,p)
        y = jnp.einsum("bhn,bhnp->bhp", Cr, hnew)[:, None]
        new_state = (conv_state_new, hnew)

    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"], new_state
