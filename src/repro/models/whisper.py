"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv/mel audio frontend is a STUB per the assignment: ``input_specs``
provides pre-computed frame embeddings (B, enc_frames, d_model). Learned
absolute positions, LayerNorm (scale+bias), GELU MLP, MHA with biases —
matching the original architecture. Decoder positions are sized for the
largest assigned shape (32k); the real model's 448 is noted in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from . import layers as L
from .params import ParamDef

DEC_POS_MAX = 32768


def _attn_t(cfg: ArchConfig, n: int, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamDef((n, d, cfg.n_heads, hd), ("layers", "embed", "heads", None),
                       "scaled"),
        "bq": ParamDef((n, cfg.n_heads, hd), ("layers", "heads", None), "zeros"),
        "wk": ParamDef((n, d, cfg.n_kv_heads, hd),
                       ("layers", "embed", "kv_heads", None), "scaled"),
        "wv": ParamDef((n, d, cfg.n_kv_heads, hd),
                       ("layers", "embed", "kv_heads", None), "scaled"),
        "bv": ParamDef((n, cfg.n_kv_heads, hd), ("layers", "kv_heads", None), "zeros"),
        "wo": ParamDef((n, cfg.n_heads, hd, d), ("layers", "heads", None, "embed"),
                       "scaled"),
        "bo": ParamDef((n, d), ("layers", None), "zeros"),
    }


def _mlp_t(cfg: ArchConfig, n: int):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_up": ParamDef((n, d, f), ("layers", "embed", "ffn"), "scaled"),
        "b_up": ParamDef((n, f), ("layers", "ffn"), "zeros"),
        "w_down": ParamDef((n, f, d), ("layers", "ffn", "embed"), "scaled"),
        "b_down": ParamDef((n, d), ("layers", None), "zeros"),
    }


def _ln_t(cfg, n, name):
    return {
        f"{name}_s": ParamDef((n, cfg.d_model), ("layers", None), "ones"),
        f"{name}_b": ParamDef((n, cfg.d_model), ("layers", None), "zeros"),
    }


def template(cfg: ArchConfig):
    d = cfg.d_model
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    enc = {"attn": _attn_t(cfg, ne), "mlp": _mlp_t(cfg, ne),
           **_ln_t(cfg, ne, "ln1"), **_ln_t(cfg, ne, "ln2")}
    dec = {"self": _attn_t(cfg, nd), "cross": _attn_t(cfg, nd),
           "mlp": _mlp_t(cfg, nd), **_ln_t(cfg, nd, "ln1"),
           **_ln_t(cfg, nd, "ln15"), **_ln_t(cfg, nd, "ln2")}
    return {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02),
        "pos_enc": ParamDef((cfg.enc_frames, d), (None, "embed"), "normal", 0.01),
        "pos_dec": ParamDef((DEC_POS_MAX, d), (None, "embed"), "normal", 0.01),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm_s": ParamDef((d,), (None,), "ones"),
        "enc_norm_b": ParamDef((d,), (None,), "zeros"),
        "dec_norm_s": ParamDef((d,), (None,), "ones"),
        "dec_norm_b": ParamDef((d,), (None,), "zeros"),
    }


def _ln(x, p, name, eps):
    return L.layer_norm(x, p[f"{name}_s"], p[f"{name}_b"], eps)


def _mha(lp, hq, hkv, *, causal, impl, q_offset=0):
    q = jnp.einsum("bsd,dhk->bshk", hq, lp["wq"]) + lp["bq"][None, None]
    k = jnp.einsum("bsd,dhk->bshk", hkv, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hkv, lp["wv"]) + lp["bv"][None, None]
    o = L.attention(q, k, v, causal=causal, impl=impl, q_offset=q_offset)
    return jnp.einsum("bshk,hkd->bsd", o, lp["wo"]) + lp["bo"][None, None]


def _mlp(lp, x):
    return jax.nn.gelu(x @ lp["w_up"] + lp["b_up"]) @ lp["w_down"] + lp["b_down"]


def encode(params, frames, cfg: ArchConfig, *, impl="chunked", remat=True):
    """frames (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    x = frames + params["pos_enc"][None, : frames.shape[1]].astype(frames.dtype)

    def body(x, lp):
        def fn(p, h):
            hn = _ln(h, p, "ln1", cfg.norm_eps)
            h = h + _mha(p["attn"], hn, hn, causal=False, impl=impl)
            return h + _mlp(p["mlp"], _ln(h, p, "ln2", cfg.norm_eps))
        if remat:
            fn = jax.checkpoint(fn)
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_norm_s"], params["enc_norm_b"], cfg.norm_eps)


def forward(params, tokens, cfg: ArchConfig, *, frames=None, impl="chunked",
            remat=True, act_spec=None, **_):
    """Teacher-forced decoder over ``tokens`` with encoder on ``frames``."""
    b, s = tokens.shape
    if frames is None:  # smoke/train convenience: zero audio
        frames = jnp.zeros((b, cfg.enc_frames, cfg.d_model),
                           params["embed"].dtype)
    enc = encode(params, frames, cfg, impl=impl, remat=remat)
    x = params["embed"][tokens] + params["pos_dec"][None, :s].astype(
        params["embed"].dtype)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
        enc = jax.lax.with_sharding_constraint(enc, act_spec)

    def body(x, lp):
        def fn(p, h):
            h = h + _mha(p["self"], _ln(h, p, "ln1", cfg.norm_eps),
                         _ln(h, p, "ln1", cfg.norm_eps), causal=True, impl=impl)
            h = h + _mha(p["cross"], _ln(h, p, "ln15", cfg.norm_eps), enc,
                         causal=False, impl=impl)
            return h + _mlp(p["mlp"], _ln(h, p, "ln2", cfg.norm_eps))
        if remat:
            fn = jax.checkpoint(fn)
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.layer_norm(x, params["dec_norm_s"], params["dec_norm_b"], cfg.norm_eps)
    return x @ params["embed"].T, 0.0


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    n = cfg.n_layers
    return {
        "k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype),
        # cross-attention K/V computed once at prefill from encoder states
        "xk": jnp.zeros((n, batch, cfg.enc_frames, cfg.n_kv_heads, hd), dtype),
        "xv": jnp.zeros((n, batch, cfg.enc_frames, cfg.n_kv_heads, hd), dtype),
    }


def decode_step(params, tokens, cache, pos, cfg: ArchConfig, **_):
    """One decoder token against self-cache + precomputed cross K/V."""
    b = tokens.shape[0]
    x = (params["embed"][tokens]
         + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, 0)[0]
         ).astype(params["embed"].dtype)[:, None]

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        h = _ln(x, lp, "ln1", cfg.norm_eps)[:, 0]
        q = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wq"]) + lp["self"]["bq"]
        k = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wv"]) + lp["self"]["bv"]
        kc = jax.lax.dynamic_update_slice(kc, k[:, None].astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[:, None].astype(vc.dtype),
                                          (0, pos, 0, 0))
        cur = jnp.full((b,), pos + 1, jnp.int32)
        a = L.attention_decode(q, kc, vc, cur)
        x = x + (jnp.einsum("bhk,hkd->bd", a, lp["self"]["wo"])
                 + lp["self"]["bo"])[:, None]
        # cross attention against the precomputed encoder K/V
        h2 = _ln(x, lp, "ln15", cfg.norm_eps)[:, 0]
        q2 = jnp.einsum("bd,dhk->bhk", h2, lp["cross"]["wq"]) + lp["cross"]["bq"]
        cur2 = jnp.full((b,), xk.shape[1], jnp.int32)
        a2 = L.attention_decode(q2, xk, xv, cur2)
        x = x + (jnp.einsum("bhk,hkd->bd", a2, lp["cross"]["wo"])
                 + lp["cross"]["bo"])[:, None]
        x = x + _mlp(lp["mlp"], _ln(x, lp, "ln2", cfg.norm_eps))
        return x, (kc, vc)

    x, (knew, vnew) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    new_cache = dict(cache, k=knew, v=vnew)
    x = L.layer_norm(x, params["dec_norm_s"], params["dec_norm_b"], cfg.norm_eps)
    return (x[:, 0] @ params["embed"].T), new_cache
