"""Unified decoder-only LM: covers stablelm / danube / granite / qwen3 /
chameleon (dense, GQA, SWA, qk-norm) and deepseek-v3 / kimi-k2 (MLA + MoE).

Layers are *stacked* (leading 'layers' axis) and applied with lax.scan — one
layer body in the HLO regardless of depth (critical for 512-device dry-run
compile times). MoE models have two stacks: the leading dense layers and the
MoE layers.

Three entry points:
  forward(params, tokens)                          -> logits       (training)
  prefill(params, tokens, cache)                   -> logits, cache
  decode_step(params, token, cache, pos)           -> logits, cache

MLA decode uses weight absorption: only the compressed c_kv / k_rope are
cached (573 floats/token for deepseek-v3 instead of 32k — the whole point of
MLA), and W_kv_b is folded into the query/output projections.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from . import layers as L
from .params import ParamDef


# ------------------------------------------------------------------ templates
def _attn_template(cfg: ArchConfig, n: int):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        t = {
            "wq_a": ParamDef((n, d, m.q_lora_rank), ("layers", "embed", None), "scaled"),
            "q_norm": ParamDef((n, m.q_lora_rank), ("layers", None), "ones"),
            "wq_b": ParamDef((n, m.q_lora_rank, cfg.n_heads, qk),
                             ("layers", None, "heads", None), "scaled"),
            "wkv_a": ParamDef((n, d, m.kv_lora_rank + m.qk_rope_dim),
                              ("layers", "embed", None), "scaled"),
            "kv_norm": ParamDef((n, m.kv_lora_rank), ("layers", None), "ones"),
            "wkv_b": ParamDef((n, m.kv_lora_rank, cfg.n_heads,
                               m.qk_nope_dim + m.v_head_dim),
                              ("layers", None, "heads", None), "scaled"),
            "wo": ParamDef((n, cfg.n_heads, m.v_head_dim, d),
                           ("layers", "heads", None, "embed"), "scaled"),
        }
        return t
    t = {
        "wq": ParamDef((n, d, cfg.n_heads, hd), ("layers", "embed", "heads", None),
                       "scaled"),
        "wk": ParamDef((n, d, cfg.n_kv_heads, hd),
                       ("layers", "embed", "kv_heads", None), "scaled"),
        "wv": ParamDef((n, d, cfg.n_kv_heads, hd),
                       ("layers", "embed", "kv_heads", None), "scaled"),
        "wo": ParamDef((n, cfg.n_heads, hd, d), ("layers", "heads", None, "embed"),
                       "scaled"),
    }
    if cfg.qk_norm:
        t["qn"] = ParamDef((n, hd), ("layers", None), "ones")
        t["kn"] = ParamDef((n, hd), ("layers", None), "ones")
    return t


def _stack_mlp(cfg: ArchConfig, n: int):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_up": ParamDef((n, d, f), ("layers", "embed", "ffn"), "scaled"),
        "w_gate": ParamDef((n, d, f), ("layers", "embed", "ffn"), "scaled"),
        "w_down": ParamDef((n, f, d), ("layers", "ffn", "embed"), "scaled"),
    }


def _stack_moe(cfg: ArchConfig, n: int):
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.n_experts, mo.d_expert
    t = {
        "router": ParamDef((n, d, e), ("layers", "embed", None), "scaled"),
        "w_gate": ParamDef((n, e, d, f), ("layers", "experts", "embed", "expert_ff"),
                           "scaled"),
        "w_up": ParamDef((n, e, d, f), ("layers", "experts", "embed", "expert_ff"),
                         "scaled"),
        "w_down": ParamDef((n, e, f, d), ("layers", "experts", "expert_ff", "embed"),
                           "scaled"),
    }
    if mo.n_shared:
        ds = (mo.d_shared or mo.d_expert) * mo.n_shared
        t["shared"] = {
            "w_up": ParamDef((n, d, ds), ("layers", "embed", "ffn"), "scaled"),
            "w_gate": ParamDef((n, d, ds), ("layers", "embed", "ffn"), "scaled"),
            "w_down": ParamDef((n, ds, d), ("layers", "ffn", "embed"), "scaled"),
        }
    return t


def _block_template(cfg: ArchConfig, n: int, moe: bool):
    t = {
        "ln1": ParamDef((n, cfg.d_model), ("layers", None), "ones"),
        "ln2": ParamDef((n, cfg.d_model), ("layers", None), "ones"),
        "attn": _attn_template(cfg, n),
        "mlp": _stack_moe(cfg, n) if moe else _stack_mlp(cfg, n),
    }
    return t


def template(cfg: ArchConfig):
    d = cfg.d_model
    t = {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02),
        "final_norm": ParamDef((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        t["unembed"] = ParamDef((d, cfg.vocab), ("embed", "vocab"), "scaled")
    if cfg.moe is not None:
        nd, nm = cfg.moe.first_dense, cfg.n_layers - cfg.moe.first_dense
        if nd:
            t["dense_blocks"] = _block_template(
                dataclasses.replace(cfg), nd, moe=False)
        t["moe_blocks"] = _block_template(cfg, nm, moe=True)
    else:
        t["blocks"] = _block_template(cfg, cfg.n_layers, moe=False)
    return t


# ------------------------------------------------------------------ attention
def _attn_dense(lp, h, cfg: ArchConfig, *, positions, impl, cache=None,
                cache_pos=None, window):
    """Standard (GQA) attention. h (B,S,D). Returns out, (k,v) for caching."""
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["qn"], cfg.norm_eps)
        k = L.rms_norm(k, lp["kn"], cfg.norm_eps)
    freqs = L.rope_frequencies(hd, cfg.rope_pct, cfg.rope_theta, positions)
    q = L.apply_rope(q, freqs)
    k = L.apply_rope(k, freqs)
    out = L.attention(q, k, v, causal=True, window=window, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), (k, v)


def _attn_dense_decode(lp, h, cfg: ArchConfig, *, pos, cache, window):
    """h (B,1,D); cache dict with k/v (B,T,KV,hd) (ring buffer when windowed)."""
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    hq = h[:, 0]
    q = jnp.einsum("bd,dhk->bhk", hq, lp["wq"])
    k = jnp.einsum("bd,dhk->bhk", hq, lp["wk"])
    v = jnp.einsum("bd,dhk->bhk", hq, lp["wv"])
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["qn"], cfg.norm_eps)
        k = L.rms_norm(k, lp["kn"], cfg.norm_eps)
    posv = jnp.full((b,), pos, jnp.int32)
    freqs = L.rope_frequencies(hd, cfg.rope_pct, cfg.rope_theta, posv)
    q = L.apply_rope(q[:, None], (freqs[0][:, None], freqs[1][:, None], freqs[2])
                     if freqs else None)[:, 0]
    k = L.apply_rope(k[:, None], (freqs[0][:, None], freqs[1][:, None], freqs[2])
                     if freqs else None)[:, 0]
    t = cache["k"].shape[1]
    slot = pos % t if window is not None else pos
    kc = jax.lax.dynamic_update_slice(cache["k"], k[:, None].astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v[:, None].astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cur = jnp.full((b,), pos + 1, jnp.int32)
    out = L.attention_decode(q, kc, vc, cur, window=window)
    return jnp.einsum("bhk,hkd->bd", out, lp["wo"])[:, None], {"k": kc, "v": vc}


def _attn_mla(lp, h, cfg: ArchConfig, *, positions, impl, window):
    """MLA training/prefill path (full expansion). Returns out, (c_kv, k_rope)."""
    m = cfg.mla
    b, s, _ = h.shape
    q_lat = L.rms_norm(jnp.einsum("bsd,dr->bsr", h, lp["wq_a"]), lp["q_norm"],
                       cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, lp["wq_b"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    kv_a = jnp.einsum("bsd,dr->bsr", h, lp["wkv_a"])
    c_kv = L.rms_norm(kv_a[..., : m.kv_lora_rank], lp["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:]                       # (B,S,rope)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, lp["wkv_b"])
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]

    freqs = L.rope_frequencies(m.qk_rope_dim, 1.0, cfg.rope_theta, positions)
    q_rope = L.apply_rope(q_rope, freqs)
    k_rope_r = L.apply_rope(k_rope[:, :, None, :], freqs)     # single kv head
    k_rope_b = jnp.broadcast_to(k_rope_r, (b, s, cfg.n_heads, m.qk_rope_dim))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = L.attention(q_full, k_full, v, causal=True, window=window, impl=impl)
    proj = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
    return proj, (c_kv, L.apply_rope(k_rope[:, :, None, :], freqs)[:, :, 0])


def _attn_mla_decode(lp, h, cfg: ArchConfig, *, pos, cache):
    """MLA decode with weight absorption; cache holds c_kv (B,T,r), k_rope."""
    m = cfg.mla
    b = h.shape[0]
    hq = h[:, 0]
    q_lat = L.rms_norm(hq @ lp["wq_a"], lp["q_norm"], cfg.norm_eps)
    q = jnp.einsum("br,rhk->bhk", q_lat, lp["wq_b"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    kv_a = hq @ lp["wkv_a"]
    c_kv = L.rms_norm(kv_a[..., : m.kv_lora_rank], lp["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:]

    posv = jnp.full((b,), pos, jnp.int32)
    freqs = L.rope_frequencies(m.qk_rope_dim, 1.0, cfg.rope_theta, posv)
    fq = (freqs[0][:, None], freqs[1][:, None], freqs[2])
    q_rope = L.apply_rope(q_rope[:, None], fq)[:, 0]
    k_rope = L.apply_rope(k_rope[:, None, None, :], fq)[:, 0, 0]

    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv[:, None].astype(cache["c_kv"].dtype), (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope[:, None].astype(cache["k_rope"].dtype), (0, pos, 0))

    # absorption: q_eff = q_nope @ W_kvb[:, :, :nope]ᵀ  -> latent space
    wk = lp["wkv_b"][..., : m.qk_nope_dim]                     # (r, H, nope)
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope, wk)             # (B,H,r)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    lat = jnp.einsum("bhr,btr->bht", q_eff, ck.astype(jnp.float32))
    rop = jnp.einsum("bhk,btk->bht", q_rope, kr.astype(jnp.float32))
    logits = (lat + rop) * scale
    valid = jnp.arange(ck.shape[1])[None, :] <= pos
    logits = jnp.where(valid[:, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    lat_out = jnp.einsum("bht,btr->bhr", w, ck.astype(jnp.float32))  # (B,H,r)
    wv = lp["wkv_b"][..., m.qk_nope_dim:]                       # (r, H, v)
    out = jnp.einsum("bhr,rhk->bhk", lat_out.astype(h.dtype), wv)
    proj = jnp.einsum("bhk,hkd->bd", out, lp["wo"])
    return proj[:, None], {"c_kv": ck, "k_rope": kr}


# --------------------------------------------------------------------- blocks
def _block(lp, x, cfg: ArchConfig, *, moe: bool, positions, impl, n_groups,
           collect=None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, _ = _attn_mla(lp["attn"], h, cfg, positions=positions, impl=impl,
                         window=cfg.window)
    else:
        a, _ = _attn_dense(lp["attn"], h, cfg, positions=positions, impl=impl,
                           window=cfg.window)
    x = x + a
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe:
        b, s, d = h2.shape
        y, aux = L.moe_apply(lp["mlp"], h2.reshape(b * s, d), cfg.moe,
                             n_groups=n_groups, act=cfg.act)
        y = y.reshape(b, s, d)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h2, cfg.act), 0.0
    out = x + y
    # harvest sites (data/activations.py): the post-block residual stream or
    # the MLP branch output (pre-residual-add) — the two streams SAEs are
    # trained on in the interpretability literature
    cap = None if collect is None else (out if collect == "resid" else y)
    return out, aux, cap


def _scan_blocks(blocks, x, cfg, *, moe, positions, impl, n_groups, remat=True,
                 collect=None):
    def body(carry, lp):
        x, aux = carry
        fn = functools.partial(_block, cfg=cfg, moe=moe, positions=positions,
                               impl=impl, n_groups=n_groups, collect=collect)
        if remat:
            fn = jax.checkpoint(fn)
        y, a, cap = fn(lp, x)
        return (y, aux + a), cap

    (x, aux), caps = jax.lax.scan(body, (x, 0.0), blocks)
    return x, aux, caps


def forward(params, tokens, cfg: ArchConfig, *, impl="chunked", n_groups=1,
            remat=True, act_spec=None, collect=None):
    """tokens (B, S) int32 -> logits (B, S, V). aux returned for MoE balance.

    ``act_spec``: PartitionSpec for (B, S, D) activations. The embedding
    gather otherwise inherits the table's FSDP sharding (batch replicated!) —
    constraining here pins activations to batch-sharded layout for the whole
    stack (see EXPERIMENTS.md §Perf, stablelm iteration 0).

    ``collect``: None | "resid" | "mlp" — when set, also return the per-layer
    activations stacked on a leading layer axis, shape (L, B, S, D): the
    post-block residual stream or the MLP branch output. This is the capture
    point of the SAE activation-harvesting stage (data/activations.py);
    ``remat`` is usually off for harvesting (no backward pass)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(params["final_norm"].dtype)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux = 0.0
    caps = []
    if cfg.moe is not None:
        if cfg.moe.first_dense:
            x, a1, c1 = _scan_blocks(params["dense_blocks"], x, cfg, moe=False,
                                     positions=positions, impl=impl,
                                     n_groups=n_groups, remat=remat,
                                     collect=collect)
            aux += a1
            caps.append(c1)
        x, a2, c2 = _scan_blocks(params["moe_blocks"], x, cfg, moe=True,
                                 positions=positions, impl=impl,
                                 n_groups=n_groups, remat=remat,
                                 collect=collect)
        aux += a2
        caps.append(c2)
    else:
        x, _, c = _scan_blocks(params["blocks"], x, cfg, moe=False,
                               positions=positions, impl=impl,
                               n_groups=n_groups, remat=remat, collect=collect)
        caps.append(c)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    un = params.get("unembed")
    logits = x @ un if un is not None else x @ params["embed"].T
    if collect is not None:
        acts = caps[0] if len(caps) == 1 else jnp.concatenate(caps, axis=0)
        return logits, aux, acts
    return logits, aux


# -------------------------------------------------------------------- serving
def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked per-layer cache pytree. Windowed archs get ring buffers."""
    t = min(max_len, cfg.window) if cfg.window else max_len
    n = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((n, batch, t, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((n, batch, t, m.qk_rope_dim), dtype),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((n, batch, t, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n, batch, t, cfg.n_kv_heads, hd), dtype),
    }


def cache_specs(cfg: ArchConfig, rules, mesh_shape):
    """PartitionSpecs for the cache: batch -> data, seq -> model (flash-decode)."""
    from jax.sharding import PartitionSpec as P
    batch_ax = rules.get("batch")
    seq_ax = rules.get("cache_seq")
    if cfg.mla is not None:
        return {"c_kv": P(None, batch_ax, seq_ax, None),
                "k_rope": P(None, batch_ax, seq_ax, None)}
    return {"k": P(None, batch_ax, seq_ax, None, None),
            "v": P(None, batch_ax, seq_ax, None, None)}


def _stacked_blocks_for_decode(params, cfg):
    """(blocks_tree, moe_flags) — blocks concatenated dense-first for MoE."""
    if cfg.moe is None:
        return [(params["blocks"], False, cfg.n_layers)]
    out = []
    if cfg.moe.first_dense:
        out.append((params["dense_blocks"], False, cfg.moe.first_dense))
    out.append((params["moe_blocks"], True, cfg.n_layers - cfg.moe.first_dense))
    return out


def decode_step(params, tokens, cache, pos, cfg: ArchConfig, *, n_groups=1):
    """One token for the whole batch. tokens (B,) int32; pos: python/traced int.
    Returns (logits (B, V), new_cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None].astype(params["final_norm"].dtype)
    layer_off = 0
    new_cache = {k: [] for k in cache}

    for blocks, moe, n in _stacked_blocks_for_decode(params, cfg):
        cache_slice = {k: v[layer_off:layer_off + n] for k, v in cache.items()}

        def body(x, xs, moe=moe):
            lp, cl = xs
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                a, cnew = _attn_mla_decode(lp["attn"], h, cfg, pos=pos, cache=cl)
            else:
                a, cnew = _attn_dense_decode(lp["attn"], h, cfg, pos=pos,
                                             cache=cl, window=cfg.window)
            x = x + a
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if moe:
                y, _ = L.moe_apply(lp["mlp"], h2[:, 0], cfg.moe,
                                   n_groups=n_groups, act=cfg.act)
                y = y[:, None]
            else:
                y = L.mlp_apply(lp["mlp"], h2, cfg.act)
            return x + y, cnew

        x, upd = jax.lax.scan(body, x, (blocks, cache_slice))
        for k in cache:
            new_cache[k].append(upd[k])
        layer_off += n

    merged = {k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
              for k, v in new_cache.items()}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    un = params.get("unembed")
    logits = (x[:, 0] @ un) if un is not None else x[:, 0] @ params["embed"].T
    return logits, merged
