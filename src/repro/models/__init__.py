"""repro.models — unified API over all assigned architecture families.

    api = models.get(cfg)        # family-dispatched function bundle
    params = params.init_params(api.template(cfg), key, dtype)
    logits, aux = api.forward(params, tokens, cfg, ...)
    cache = api.make_cache(cfg, batch, max_len)      (None for train-only SAE)
    logits, cache = api.decode_step(params, toks, cache, pos, cfg)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.configs.types import ArchConfig
from . import lm, params, sae, whisper, xlstm, zamba  # noqa: F401
from . import layers  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    template: Callable
    forward: Callable
    make_cache: Optional[Callable] = None
    decode_step: Optional[Callable] = None


def get(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelAPI(lm.template, lm.forward, lm.make_cache, lm.decode_step)
    if fam == "audio":
        return ModelAPI(whisper.template, whisper.forward, whisper.make_cache,
                        whisper.decode_step)
    if fam == "ssm":
        return ModelAPI(xlstm.template, xlstm.forward,
                        lambda cfg, b, _len, dtype=None: xlstm.make_state(cfg, b),
                        xlstm.decode_step)
    if fam == "hybrid":
        return ModelAPI(zamba.template, zamba.forward, zamba.make_cache,
                        zamba.decode_step)
    if fam == "sae":
        return ModelAPI(sae.template, sae.forward)
    raise ValueError(f"unknown family {fam!r}")
