"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory).

Layout for xlstm-1.3b: 48 layers in super-blocks of (slstm_every-1) mLSTM
followed by 1 sLSTM, scanned over super-blocks. The mLSTM has both a
*sequential* recurrence (the faithful formulation — also the decode path) and
a *chunkwise-parallel* formulation (production path for training; validated
against the sequential one in tests). Both use the exponential-gating
stabilizer m_t from the paper.

Gates are exp(i)/exp(f) with running max stabilization; the normalizer is
max(|q·n|, exp(-m)) exactly as in the paper's Appendix.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from . import layers as L
from .params import ParamDef

_NEG = -1e30


# -------------------------------------------------------------------- mLSTM
def mlstm_sequential(q, k, v, li, lf, state=None):
    """q,k,v (b,s,h,d); li/lf (b,s,h) log gates. Returns y, final state.

    state = (C (b,h,dk,dv), n (b,h,dk), m (b,h)).
    """
    b, s, h, d = q.shape
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), _NEG, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)[..., None]
        ip = jnp.exp(lit - m_new)[..., None]
        C = C * fp[..., None] + ip[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = n * fp + ip * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        qn = jnp.einsum("bhd,bhd->bh", qt, n)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / denom

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (qf, kf, vf)) + tuple(
        a.transpose(1, 0, 2) for a in (li.astype(jnp.float32), lf.astype(jnp.float32)))
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    return ys.transpose(1, 0, 2, 3).astype(q.dtype), (C, n, m)


def mlstm_chunkwise(q, k, v, li, lf, *, chunk: int, state=None):
    """Chunkwise-parallel mLSTM — O(s·c) intra + O(s/c) recurrence."""
    b, s, h, d = q.shape
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, padq) for a in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=_NEG)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    qf = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, nc, c, h, d)
    kf = k.astype(jnp.float32).reshape(b, nc, c, h, d)
    vf = v.astype(jnp.float32).reshape(b, nc, c, h, d)
    lif = li.astype(jnp.float32).reshape(b, nc, c, h)
    lff = lf.astype(jnp.float32).reshape(b, nc, c, h)

    cumf = jnp.cumsum(lff, axis=2)                               # inclusive
    # D[i,j] = cumf_i - cumf_j + li_j  (j <= i)
    D = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + lif[:, :, None, :, :]
    ii = jnp.arange(c)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    D = jnp.where(causal, D, _NEG)
    m_intra = jnp.max(D, axis=3)                                 # (b,nc,c,h)
    sdot = jnp.einsum("bzihd,bzjhd->bzijh", qf, kf)              # raw q·k scores

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), _NEG, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        Cs, ns, ms = carry
        qz, kz, vz, cumf_z, li_z, D_z, mi_z, sd_z = inp
        m_i = jnp.maximum(mi_z, cumf_z + ms[:, None])            # (b,c,h)
        w = jnp.exp(D_z - m_i[:, :, None])                       # (b,i,j,h)
        num = jnp.einsum("bijh,bijh,bjhe->bihe", sd_z, w, vz)
        qC = jnp.einsum("bihd,bhde->bihe", qz, Cs)
        inter = jnp.exp(cumf_z + ms[:, None] - m_i)              # (b,c,h)
        num = num + qC * inter[..., None]
        qn = jnp.einsum("bijh,bijh->bih", sd_z, w)
        qn = qn + jnp.einsum("bihd,bhd->bih", qz, ns) * inter
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
        y = num / denom[..., None]
        # carry update to end of chunk
        f_end = cumf_z[:, -1]                                    # (b,h)
        g = f_end[:, None] - cumf_z + li_z                       # (b,c,h)
        m_out = jnp.maximum(jnp.max(g, axis=1), f_end + ms)
        wC = jnp.exp(g - m_out[:, None])                         # (b,c,h)
        C_new = (Cs * jnp.exp(f_end + ms - m_out)[..., None, None]
                 + jnp.einsum("bch,bchd,bche->bhde", wC, kz, vz))
        n_new = (ns * jnp.exp(f_end + ms - m_out)[..., None]
                 + jnp.einsum("bch,bchd->bhd", wC, kz))
        return (C_new, n_new, m_out), y

    xs = (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), cumf.transpose(1, 0, 2, 3),
          lif.transpose(1, 0, 2, 3), D.transpose(1, 0, 2, 3, 4),
          m_intra.transpose(1, 0, 2, 3), sdot.transpose(1, 0, 2, 3, 4))
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, d)
    return y[:, :s].astype(q.dtype), (C, n, m)


# ------------------------------------------------------------------ templates
def _mlstm_template(cfg: ArchConfig, n: int):
    d = cfg.d_model
    di = int(d * cfg.xlstm.proj_factor)
    h = cfg.n_heads
    return {
        "ln": ParamDef((n, d), ("layers", None), "ones"),
        "w_up": ParamDef((n, d, 2 * di), ("layers", "embed", "ffn"), "scaled"),
        # per-head block-diagonal q/k/v, as in the official mLSTM (di²/h each)
        "wq": ParamDef((n, h, di // h, di // h), ("layers", "heads", None, None),
                       "scaled"),
        "wk": ParamDef((n, h, di // h, di // h), ("layers", "heads", None, None),
                       "scaled"),
        "wv": ParamDef((n, h, di // h, di // h), ("layers", "heads", None, None),
                       "scaled"),
        "w_gates": ParamDef((n, di, 2 * h), ("layers", "ffn", None), "scaled"),
        "gn": ParamDef((n, di), ("layers", None), "ones"),
        "w_down": ParamDef((n, di, d), ("layers", "ffn", "embed"), "scaled"),
    }


def _slstm_template(cfg: ArchConfig, n: int):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    f = int(d * 4 / 3)
    # shard_r: the recurrent matrices are re-read every timestep; TP-sharding
    # their output dim divides that traffic by the model-axis size at the cost
    # of one tiny (b·h·dh floats) all-gather of h_{t-1} per step.
    r_axes = ("layers", None, "heads", None, "ffn") if cfg.xlstm.shard_r \
        else ("layers", None, "heads", None, None)
    return {
        "ln": ParamDef((n, d), ("layers", None), "ones"),
        "w_in": ParamDef((n, d, 4 * d), ("layers", "embed", "ffn"), "scaled"),
        "r": ParamDef((n, 4, h, dh, dh), r_axes, "scaled"),
        "gn": ParamDef((n, d), ("layers", None), "ones"),
        "ln2": ParamDef((n, d), ("layers", None), "ones"),
        "w_up": ParamDef((n, d, 2 * f), ("layers", "embed", "ffn"), "scaled"),
        "w_down": ParamDef((n, f, d), ("layers", "ffn", "embed"), "scaled"),
    }


def template(cfg: ArchConfig):
    xl = cfg.xlstm
    n_super = cfg.n_layers // xl.slstm_every
    n_m_per = xl.slstm_every - 1
    return {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), "normal", 0.02),
        "final_norm": ParamDef((cfg.d_model,), (None,), "ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"), "scaled"),
        # (n_super, n_m_per, ...) double-stacked mLSTM params
        "mlstm": {k: ParamDef((n_super,) + pd.shape, ("super",) + pd.axes,
                              pd.init, pd.scale)
                  for k, pd in _mlstm_template(cfg, n_m_per).items()},
        "slstm": _slstm_template(cfg, n_super),
    }


# -------------------------------------------------------------------- applies
def _mlstm_block(lp, x, cfg: ArchConfig, *, seq_mode: str, state=None):
    d = cfg.d_model
    di = int(d * cfg.xlstm.proj_factor)
    h = cfg.n_heads
    dh = di // h
    b, s, _ = x.shape
    hin = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    up = hin @ lp["w_up"]
    xm, z = up[..., :di], up[..., di:]
    xh = xm.reshape(b, s, h, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, lp["wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, lp["wk"])
    v = jnp.einsum("bshd,hde->bshe", xh, lp["wv"])
    gates = (xm @ lp["w_gates"]).astype(jnp.float32)
    li, lf = gates[..., :h], gates[..., h:]
    lf = -jax.nn.softplus(-lf)  # log sigmoid forget gate
    if seq_mode == "chunkwise":
        y, st = mlstm_chunkwise(q, k, v, li, lf, chunk=cfg.xlstm.chunk, state=state)
    else:
        y, st = mlstm_sequential(q, k, v, li, lf, state=state)
    y = y.reshape(b, s, di)
    y = L.rms_norm(y, lp["gn"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ lp["w_down"], st


def _slstm_block(lp, x, cfg: ArchConfig, *, state=None):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    b, s, _ = x.shape
    hin = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    gi = (hin @ lp["w_in"]).astype(jnp.float32).reshape(b, s, 4, h, dh)
    if state is None:
        c0 = jnp.zeros((b, h, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        h0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h, dh), _NEG, jnp.float32)
    else:
        c0, n0, h0, m0 = state
    r = lp["r"].astype(jnp.float32)  # (4, heads, dh, dh)

    def step(carry, g):
        c, n, hprev, m = carry
        rec = jnp.einsum("bhd,ghde->gbhe", hprev, r)
        zt = jnp.tanh(g[:, 0] + rec[0])
        it = g[:, 1] + rec[1]
        ft = -jax.nn.softplus(-(g[:, 2] + rec[2]))  # log sigmoid
        ot = jax.nn.sigmoid(g[:, 3] + rec[3])
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        hnew = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, hnew, m_new), hnew

    (c0, n0, h0, m0), ys = jax.lax.scan(step, (c0, n0, h0, m0),
                                        gi.transpose(1, 0, 2, 3, 4))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    x = x + L.rms_norm(y, lp["gn"], cfg.norm_eps)
    # gated FFN (paper: proj factor 4/3)
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    up = h2 @ lp["w_up"]
    f = lp["w_down"].shape[0]
    y2 = (jax.nn.silu(up[..., :f]) * up[..., f:]) @ lp["w_down"]
    return x + y2, (c0, n0, h0, m0)


def forward(params, tokens, cfg: ArchConfig, *, seq_mode="chunkwise", remat=True,
            act_spec=None, **_):
    b, s = tokens.shape
    x = params["embed"][tokens].astype(params["final_norm"].dtype)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)

    def super_block(x, lp):
        def m_body(x, mlp):
            fn = lambda p, h: _mlstm_block(p, h, cfg=cfg, seq_mode=seq_mode)[0]
            if remat:
                fn = jax.checkpoint(fn)
            return fn(mlp, x), None

        x, _ = jax.lax.scan(m_body, x, lp["mlstm"])
        x, _ = _slstm_block(lp["slstm"], x, cfg)
        return x, None

    stacked = {"mlstm": params["mlstm"], "slstm": params["slstm"]}
    x, _ = jax.lax.scan(super_block, x, stacked)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"], 0.0


def make_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    """Recurrent decode state (the xLSTM 'cache'): O(1) in sequence length."""
    xl = cfg.xlstm
    n_super = cfg.n_layers // xl.slstm_every
    n_m = xl.slstm_every - 1
    d = cfg.d_model
    di = int(d * xl.proj_factor)
    h = cfg.n_heads
    dh, dhs = di // h, d // h
    return {
        "mlstm_C": jnp.zeros((n_super, n_m, batch, h, dh, dh), jnp.float32),
        "mlstm_n": jnp.zeros((n_super, n_m, batch, h, dh), jnp.float32),
        "mlstm_m": jnp.full((n_super, n_m, batch, h), _NEG, jnp.float32),
        "slstm_c": jnp.zeros((n_super, batch, h, dhs), jnp.float32),
        "slstm_n": jnp.zeros((n_super, batch, h, dhs), jnp.float32),
        "slstm_h": jnp.zeros((n_super, batch, h, dhs), jnp.float32),
        "slstm_m": jnp.full((n_super, batch, h, dhs), _NEG, jnp.float32),
    }


def decode_step(params, tokens, state, pos, cfg: ArchConfig, **_):
    """One token; state as from make_state. Returns (logits, new_state)."""
    x = params["embed"][tokens][:, None].astype(params["final_norm"].dtype)

    def super_block(x, xs):
        lp, st = xs

        def m_body(x, inp):
            mlp, C, n, m = inp
            y, (C2, n2, m2) = _mlstm_block(mlp, x, cfg, seq_mode="sequential",
                                           state=(C, n, m))
            return y, (C2, n2, m2)

        x, (C2, n2, m2) = jax.lax.scan(
            m_body, x, (lp["mlstm"], st["mlstm_C"], st["mlstm_n"], st["mlstm_m"]))
        x, (c, n, h, m) = _slstm_block(
            lp["slstm"], x, cfg,
            state=(st["slstm_c"], st["slstm_n"], st["slstm_h"], st["slstm_m"]))
        new = {"mlstm_C": C2, "mlstm_n": n2, "mlstm_m": m2,
               "slstm_c": c, "slstm_n": n, "slstm_h": h, "slstm_m": m}
        return x, new

    stacked = ({"mlstm": params["mlstm"], "slstm": params["slstm"]}, state)
    x, new_state = jax.lax.scan(super_block, x, stacked)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x[:, 0] @ params["unembed"]), new_state
