"""Zamba2-7B (arXiv:2411.15242): Mamba2 backbone + ONE weight-shared
attention+MLP block applied every ``attn_every`` layers.

81 layers = 13 super-groups of (5 mamba + 1 shared-attn application) + 3
trailing mamba layers. The shared block receives concat(x, x0) (original
embeddings re-injected, as in Zamba) projected back to d_model; per-application
LoRA specialization of the shared block is omitted (DESIGN.md §7).

At sequence lengths >= hybrid.long_seq the shared attention switches to a
sliding window (hybrid.window_at_long) — this is what makes the `long_500k`
shape runnable with an O(window) cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from . import layers as L
from .params import ParamDef


def _n_groups_trailing(cfg: ArchConfig):
    k = cfg.hybrid.attn_every
    n_super = cfg.n_layers // k
    trailing = cfg.n_layers - n_super * k
    return n_super, k - 1, trailing


def template(cfg: ArchConfig):
    d = cfg.d_model
    n_super, m_per, trailing = _n_groups_trailing(cfg)
    mamba = lambda n: {k: v for k, v in L.mamba2_template(d, cfg.ssm).items()}

    def stack(t, n):
        return {k: ParamDef((n,) + pd.shape, ("layers",) + pd.axes, pd.init,
                            pd.scale) for k, pd in t.items()}

    hd = cfg.resolved_head_dim
    shared = {
        "w_concat": ParamDef((2 * d, d), ("embed", None), "scaled"),
        "ln1": ParamDef((d,), (None,), "ones"),
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed", "heads", None), "scaled"),
        "wk": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None), "scaled"),
        "wv": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None), "scaled"),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", None, "embed"), "scaled"),
        "ln2": ParamDef((d,), (None,), "ones"),
        "mlp": L.mlp_template(d, cfg.d_ff, cfg.act),
        "norm_m": ParamDef((d,), (None,), "ones"),
    }
    return {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02),
        "final_norm": ParamDef((d,), (None,), "ones"),
        "unembed": ParamDef((d, cfg.vocab), ("embed", "vocab"), "scaled"),
        "mamba_norm": {
            "super": ParamDef((n_super, m_per, d), ("layers", None, None), "ones"),
            "trailing": ParamDef((trailing, d), ("layers", None), "ones"),
        },
        "mamba_super": {k: ParamDef((n_super,) + pd.shape, ("super",) + pd.axes,
                                    pd.init, pd.scale)
                        for k, pd in stack(mamba(0), m_per).items()},
        "mamba_trailing": stack(mamba(0), trailing),
        "shared": shared,
    }


def _shared_attn(sp, x, x0, cfg: ArchConfig, *, positions, impl, window,
                 cache=None, pos=None):
    """The weight-shared transformer block. Returns (x_out, new kv) ."""
    h = jnp.concatenate([x, x0], axis=-1) @ sp["w_concat"]
    hn = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
    hd = cfg.resolved_head_dim
    if cache is None:
        q = jnp.einsum("bsd,dhk->bshk", hn, sp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", hn, sp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hn, sp["wv"])
        freqs = L.rope_frequencies(hd, cfg.rope_pct, cfg.rope_theta, positions)
        q, k = L.apply_rope(q, freqs), L.apply_rope(k, freqs)
        a = L.attention(q, k, v, causal=True, window=window, impl=impl)
        a = jnp.einsum("bshk,hkd->bsd", a, sp["wo"])
        newkv = None
    else:
        b = x.shape[0]
        hq = hn[:, 0]
        q = jnp.einsum("bd,dhk->bhk", hq, sp["wq"])
        k = jnp.einsum("bd,dhk->bhk", hq, sp["wk"])
        v = jnp.einsum("bd,dhk->bhk", hq, sp["wv"])
        posv = jnp.full((b,), pos, jnp.int32)
        freqs = L.rope_frequencies(hd, cfg.rope_pct, cfg.rope_theta, posv)
        fq = (freqs[0][:, None], freqs[1][:, None], freqs[2]) if freqs else None
        q = L.apply_rope(q[:, None], fq)[:, 0]
        k = L.apply_rope(k[:, None], fq)[:, 0]
        t = cache["k"].shape[1]
        slot = pos % t if window is not None else pos
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k[:, None].astype(cache["k"].dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v[:, None].astype(cache["v"].dtype), (0, slot, 0, 0))
        cur = jnp.full((b,), pos + 1, jnp.int32)
        a = L.attention_decode(q, kc, vc, cur, window=window)[:, None]
        a = jnp.einsum("bshk,hkd->bsd", a, sp["wo"])
        newkv = {"k": kc, "v": vc}
    h2 = h + a
    y = L.mlp_apply(sp["mlp"], L.rms_norm(h2, sp["ln2"], cfg.norm_eps), cfg.act)
    return x + a + y, newkv  # block delta re-joins the backbone stream


def _window_for(cfg: ArchConfig, seq_len: int):
    hy = cfg.hybrid
    return hy.window_at_long if seq_len >= hy.long_seq else None


def forward(params, tokens, cfg: ArchConfig, *, impl="chunked", remat=True,
            act_spec=None, **_):
    b, s = tokens.shape
    x0 = params["embed"][tokens].astype(params["final_norm"].dtype)
    if act_spec is not None:
        x0 = jax.lax.with_sharding_constraint(x0, act_spec)
    x = x0
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    window = _window_for(cfg, s)

    def mamba_body(x, xs):
        lp, norm = xs
        fn = lambda p, h: L.mamba2_apply(p, L.rms_norm(h, norm, cfg.norm_eps),
                                         cfg.ssm)[0] + h
        if remat:
            fn = jax.checkpoint(fn)
        return fn(lp, x), None

    def super_block(x, xs):
        lp, norms = xs
        x, _ = jax.lax.scan(mamba_body, x, (lp, norms))
        x, _ = _shared_attn(params["shared"], x, x0, cfg, positions=positions,
                            impl=impl, window=window)
        return x, None

    x, _ = jax.lax.scan(super_block, x,
                        (params["mamba_super"], params["mamba_norm"]["super"]))
    x, _ = jax.lax.scan(mamba_body, x,
                        (params["mamba_trailing"], params["mamba_norm"]["trailing"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"], 0.0


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Mamba recurrent states + kv ring caches for the 13 shared-attn sites."""
    n_super, m_per, trailing = _n_groups_trailing(cfg)
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.expand * d
    h = di // ssm.head_dim
    gn = ssm.n_groups * ssm.d_state
    window = _window_for(cfg, max_len)
    t = min(max_len, window) if window else max_len
    hd = cfg.resolved_head_dim
    return {
        "conv_super": jnp.zeros((n_super, m_per, batch, ssm.d_conv, di + 2 * gn), dtype),
        "ssm_super": jnp.zeros((n_super, m_per, batch, h, ssm.d_state,
                                ssm.head_dim), jnp.float32),
        "conv_trail": jnp.zeros((trailing, batch, ssm.d_conv, di + 2 * gn), dtype),
        "ssm_trail": jnp.zeros((trailing, batch, h, ssm.d_state, ssm.head_dim),
                               jnp.float32),
        "k": jnp.zeros((n_super, batch, t, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_super, batch, t, cfg.n_kv_heads, hd), dtype),
    }


def decode_step(params, tokens, cache, pos, cfg: ArchConfig, *, max_len=None, **_):
    b = tokens.shape[0]
    x0 = params["embed"][tokens][:, None].astype(params["final_norm"].dtype)
    x = x0
    window = _window_for(cfg, max_len or cache["k"].shape[2])
    if window is not None and cache["k"].shape[2] < window:
        window = cache["k"].shape[2]

    def mamba_body(x, xs):
        lp, norm, cs, ss = xs
        y, (cs2, ss2) = L.mamba2_apply(lp, L.rms_norm(x, norm, cfg.norm_eps),
                                       cfg.ssm, state=(cs, ss))
        return x + y, (cs2, ss2)

    def super_block(x, xs):
        lp, norms, cs, ss, kc, vc = xs
        x, (cs2, ss2) = jax.lax.scan(mamba_body, x, (lp, norms, cs, ss))
        x, kv = _shared_attn(params["shared"], x, x0, cfg, positions=None,
                             impl=None, window=window,
                             cache={"k": kc, "v": vc}, pos=pos)
        return x, (cs2, ss2, kv["k"], kv["v"])

    x, (cs_s, ss_s, knew, vnew) = jax.lax.scan(
        super_block, x,
        (params["mamba_super"], params["mamba_norm"]["super"],
         cache["conv_super"], cache["ssm_super"], cache["k"], cache["v"]))
    x, (cs_t, ss_t) = jax.lax.scan(
        mamba_body, x,
        (params["mamba_trailing"], params["mamba_norm"]["trailing"],
         cache["conv_trail"], cache["ssm_trail"]))
    new_cache = {"conv_super": cs_s, "ssm_super": ss_s, "conv_trail": cs_t,
                 "ssm_trail": ss_t, "k": knew, "v": vnew}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x[:, 0] @ params["unembed"]), new_cache
