"""Parameter templates: one declaration drives init, sharding, and counting.

A model is declared as a pytree of ``ParamDef`` (shape + logical axes + init).
From the same template we derive:

  * ``init_params``  — materialized arrays (deterministic per-path RNG folds)
  * ``param_specs``  — PartitionSpec tree via a logical→mesh axis rule map
                       (with divisibility checks → replicate when they fail)
  * ``count_params`` — exact parameter count without allocation

This is the MaxText-style "logical axis" pattern, reduced to the essentials.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis names (len == len(shape))
    init: str = "normal"                   # normal | zeros | ones | scaled
    scale: float = 0.02                    # stddev for 'normal'; 'scaled' -> 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map(f, template):
    return jax.tree_util.tree_map(f, template, is_leaf=is_def)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def init_params(template, key: jax.Array, dtype=jnp.float32):
    """Materialize a template. Each leaf's RNG is folded from its path string
    so layouts can be refactored without changing unrelated leaves."""

    def one(path, pd: ParamDef):
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, dtype)
        # crc32, NOT hash(): python string hashing is per-process randomized
        k = jax.random.fold_in(
            key, np.uint32(zlib.crc32(_path_str(path).encode())))
        if pd.init == "scaled":
            fan_in = pd.shape[0] if len(pd.shape) == 1 else int(np.prod(pd.shape[:-1]))
            std = 1.0 / max(np.sqrt(fan_in), 1.0)
        else:
            std = pd.scale
        return (jax.random.normal(k, pd.shape, jnp.float32) * std).astype(dtype)

    return jax.tree_util.tree_map_with_path(one, template, is_leaf=is_def)


def abstract_params(template, dtype=jnp.float32):
    """ShapeDtypeStruct tree (for dry-run lowering — no allocation)."""
    return _tree_map(lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype), template)


def param_specs(template, rules: Dict[str, Optional[str]], mesh_shape: Dict[str, int]):
    """PartitionSpec tree. ``rules`` maps logical axis -> mesh axis (or None).

    A dim is sharded only when the mapped mesh axis divides it; otherwise that
    dim replicates (correct-by-construction for ragged head counts etc.).
    A mesh axis is used at most once per param (first logical axis wins).
    """

    def one(pd: ParamDef):
        used = set()
        parts = []
        for dim, ax in zip(pd.shape, pd.axes):
            mesh_ax = rules.get(ax) if ax else None
            if mesh_ax is None:
                parts.append(None)
                continue
            # tuples of mesh axes allowed, e.g. ("pod", "data")
            axes_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            size = 1
            for a in axes_tuple:
                size *= mesh_shape[a]
            if dim % size == 0 and not (set(axes_tuple) & used):
                used.update(axes_tuple)
                parts.append(mesh_ax)
            else:
                parts.append(None)
        return P(*parts)

    return _tree_map(one, template)


def count_params(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=is_def)
    return int(sum(int(np.prod(pd.shape)) for pd in leaves))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)
