"""Fused tri-level ℓ1,∞,∞ Pallas kernels (paper Algorithm 5, DESIGN.md §4).

GOLDEN REFERENCE: since the kernel code generator landed
(``kernels/codegen``), this hand-written kernel is no longer a planner
backend — it pins the generated tri-level kernel in ``tests/test_codegen.py``
and baselines it in ``benchmarks/run.py --only codegen``.

``TP^{1,∞,∞}_η(Y)`` for Y ∈ R^{c,n,m} decomposes into

  pass 1  reduce:  v2[i,j] = max_c |Y[c,i,j]|   AND   v1[j] = max_i v2[i,j]
                   (ONE streaming pass over Y; the slice-∞ and column-∞
                   reductions are fused — v2 is produced as a byproduct of
                   accumulating v1, grid-reduced over row blocks)
  (tiny)  outer :  u1 = P¹_η(v1)                (jnp or the l1ball kernel)
  pass 2  apply :  X = clip(Y, ±min(v2, u1))    (the grouped threshold apply:
                   min(v2, u1) IS the per-(i,j) ∞-radius of the recursion)

Y is read exactly twice — same information-theoretic minimum as the bi-level
kernel; the naive composition (multilevel_project) reads Y twice *and* v2
twice more in separate dispatches. Blocks are (c, block_n, block_m) with the
whole (small) slice axis resident: c is experts/heads (≤ a few hundred) in
every assigned architecture, so a (c, 8, 128) f32 tile fits VMEM comfortably.

Grid layout mirrors bilevel_l1inf.py: the sequential row-block axis is LAST so
the v1 accumulation is legal (PARALLEL over column blocks, ARBITRARY over row
blocks); ragged row edges are masked in-kernel, ragged lane edges are dropped
on write-back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams

DEFAULT_BLOCK_N = 256   # rows per tile (sublane axis)
DEFAULT_BLOCK_M = 512   # cols per tile (lane axis)


def _reduce_kernel(y_ref, v2_ref, v1_ref, *, n_total: int, block_n: int):
    """v2 tile = max over the slice axis; v1 row = running max over row blocks."""
    i = pl.program_id(1)  # sequential row-block index (last grid axis)
    a = jnp.abs(y_ref[...])                       # (c, block_n, block_m)
    v2 = jnp.max(a, axis=0)                       # (block_n, block_m)
    # mask rows past the true edge with 0 (|.| >= 0 so 0 is the max identity)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, v2.shape, 0) + i * block_n
    v2 = jnp.where(row_ids < n_total, v2, 0.0)
    v2_ref[...] = v2
    part = jnp.max(v2, axis=0, keepdims=True)     # (1, block_m)

    @pl.when(i == 0)
    def _init():
        v1_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        v1_ref[...] = jnp.maximum(v1_ref[...], part)


def _apply_kernel(y_ref, v2_ref, u1_ref, out_ref):
    """out = clip(y, ±min(v2, u1)) — the grouped threshold apply in one tile."""
    u2 = jnp.minimum(v2_ref[...], u1_ref[...])    # (block_n, block_m), u1 bcast
    out_ref[...] = jnp.clip(y_ref[...], -u2[None], u2[None])


def trilevel_reduce_pallas(y: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                           block_m: int = DEFAULT_BLOCK_M,
                           interpret: bool = False):
    """(v2, v1) = (max_c |Y|, max_{c,i} |Y|) in one streaming pass over Y."""
    c, n, m = y.shape
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(128, m))
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    v2, v1 = pl.pallas_call(
        functools.partial(_reduce_kernel, n_total=n, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((c, block_n, block_m), lambda j, i: (0, i, j))],
        out_specs=[
            pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
            pl.BlockSpec((1, block_m), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), y.dtype),
            jax.ShapeDtypeStruct((1, m), y.dtype),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(y)
    return v2, v1[0]


def trilevel_apply_pallas(y: jax.Array, v2: jax.Array, u1: jax.Array, *,
                          block_n: int = DEFAULT_BLOCK_N,
                          block_m: int = DEFAULT_BLOCK_M,
                          interpret: bool = False) -> jax.Array:
    """X = clip(Y, ±min(v2, u1)) — per-column ∞-radius u1, per-slice max v2."""
    c, n, m = y.shape
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(128, m))
    grid = (pl.cdiv(n, block_n), pl.cdiv(m, block_m))
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, block_n, block_m), lambda i, j: (0, i, j)),
            pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((c, block_n, block_m), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((c, n, m), y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, v2, u1.reshape(1, m).astype(y.dtype))


def trilevel_l1infinf_pallas(y: jax.Array, radius, *, method: str = "bisect",
                             block_n: int = DEFAULT_BLOCK_N,
                             block_m: int = DEFAULT_BLOCK_M,
                             interpret: bool = False) -> jax.Array:
    """Fused tri-level ℓ1,∞,∞ projection: reduce → outer P¹ → apply.

    ``method`` selects the outer-step θ kernel ("bisect" | "filter" run the
    VMEM kernel; anything else — or a vector past the single-block VMEM
    limit — the jnp backend); see kernels.l1ball.
    """
    from .l1ball import outer_l1_solve

    if y.ndim != 3:
        raise ValueError("trilevel_l1infinf_pallas expects an order-3 tensor")
    v2, v1 = trilevel_reduce_pallas(y, block_n=block_n, block_m=block_m,
                                    interpret=interpret)
    u1 = outer_l1_solve(v1, radius, method=method, interpret=interpret)
    return trilevel_apply_pallas(y, v2, u1, block_n=block_n, block_m=block_m,
                                 interpret=interpret)
