"""Pallas TPU flash attention — blockwise online softmax, forward AND backward.

Used by the serving/prefill path on real TPUs (the dry-run and CPU tests use
the pure-jnp chunked oracle; see models/layers.py `attention_impl`) and, now
that it carries a custom VJP, by LM *training* on TPU — gradients no longer
fall back to the jnp oracle.

Layout: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D) with GQA group = Hq // Hkv
resolved inside the BlockSpec index maps (no kv repetition in HBM!).

Forward grid: (B, Hq, Sq/block_q, Sk/block_k) — the k axis is last
(sequential on TPU), carrying the running max/denominator/accumulator in VMEM
scratch. Causal/windowed blocks that are fully masked are skipped with
pl.when — for causal attention this halves the compute (FlashAttention-2
behaviour). The forward also emits the log-sum-exp rows ``lse = m + log(l)``,
the only softmax statistic the backward needs.

Backward (FlashAttention-2 style, two kernels + one elementwise jnp pass):

* ``delta = rowsum(dO ∘ O)`` — elementwise, jnp;
* **dQ kernel** — same grid as the forward (k sequential), recomputes the
  P-tile from (q, k, lse), accumulates ``scale · Σ_j P∘(dOVᵀ − delta) k_j``
  in VMEM scratch;
* **dK/dV kernel** — grid (B, Hkv, Sk/block_k, G·Sq/block_q) with the fused
  (group, q-block) axis last (sequential): each kv head accumulates its dk/dv
  block across all G query heads of its group and every q block in scratch,
  so GQA needs no gradient reshuffle in HBM.

Alignment: block_q/block_k multiples of 128 (lane), head dim is the minor-most
axis of every tile; pad D to a multiple of 128 outside for peak MXU mapping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _COMPILER_PARAMS_CLS

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30
_LANES = 128


def _block_mask(q_start, k_start, shape, *, causal, window, sk):
    """The (block_q, block_k) validity mask shared by forward and backward."""
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    mask = jnp.logical_and(kpos < sk, qpos < sk)   # ragged k AND q tails
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    return mask


def _block_live(q_start, k_start, *, causal, window, block_q, block_k):
    """Trace-time predicate: does this (q-block, k-block) pair contribute?"""
    run = True
    if causal:
        run = jnp.asarray(k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, jnp.asarray(k_start + block_k - 1 > q_start - window))
    if not causal and window is None:
        run = jnp.asarray(True)
    return run


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window, block_q: int,
                  block_k: int, sq: int, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = iq * block_q + (sk - sq)  # right-aligned absolute q positions
    k_start = ik * block_k
    run = _block_live(q_start, k_start, causal=causal, window=window,
                      block_q=block_q, block_k=block_k)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        # zero the ragged k/v tail: p is 0 there, but 0 * pad-NaN would poison acc
        kv_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)) < sk
        v = jnp.where(kv_valid, v, 0.0)
        k = jnp.where(kv_valid, k, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        mask = _block_mask(q_start, k_start, s.shape, causal=causal,
                           window=window, sk=sk)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0:1]                      # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # (block_q, block_k)
        corr = jnp.exp(m_prev - m_new)              # (block_q, 1)
        l_new = corr * l_ref[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _fin():
        l = l_ref[:, 0:1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        # lse rows for the backward; fully-masked rows get -inf (their p
        # recomputation is then 0 under the mask, never NaN)
        lse_ref[0, 0] = m_ref[:, 0:1] + jnp.log(denom)


def _fwd_call(q, k, v, *, causal, window, scale, block_q, block_k, interpret):
    """Forward pallas call: returns (o, lse) with lse (B, Hq, Sq) in f32."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (b, hq, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, sk=sk,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, iq, ik: (b, h, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return o, lse[..., 0]


# --------------------------------------------------------------------------- #
# Backward kernels (FlashAttention-2)
# --------------------------------------------------------------------------- #


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, acc_ref,
               *, scale: float, causal: bool, window, block_q: int,
               block_k: int, sq: int, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    q_start = iq * block_q + (sk - sq)
    k_start = ik * block_k
    run = _block_live(q_start, k_start, causal=causal, window=window,
                      block_q=block_q, block_k=block_k)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                                # (block_q, 1)
        delta = dl_ref[0, 0]                               # (block_q, 1)
        # zero ragged k/v tails: the matmuls below would turn pad-NaN into
        # NaN rows of dq even where p == 0 (0 * NaN)
        kv_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)) < sk
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(q_start, k_start, s.shape, causal=causal,
                           window=window, sk=sk)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)         # (block_q, block_k)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = jnp.where(mask, p * (dp - delta), 0.0)
        acc_ref[...] = acc_ref[...] + jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, scale: float, causal: bool, window,
                block_q: int, block_k: int, sq: int, sk: int, n_q: int):
    jk = pl.program_id(2)
    t = pl.program_id(3)       # fused (group, q-block) sequential axis
    nt = pl.num_programs(3)
    iq = t % n_q
    q_start = iq * block_q + (sk - sq)
    k_start = jk * block_k
    run = _block_live(q_start, k_start, causal=causal, window=window,
                      block_q=block_q, block_k=block_k)

    @pl.when(t == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = dl_ref[0, 0]
        # ragged tails on BOTH axes feed the accumulating matmuls here: a
        # pad-NaN q/do row (or k/v row) would poison the whole dk/dv block
        # through 0 * NaN, so zero them before any contraction
        qrow_valid = (q_start + jax.lax.broadcasted_iota(jnp.int32, q.shape, 0)) < sk
        q = jnp.where(qrow_valid, q, 0.0)
        do = jnp.where(qrow_valid, do, 0.0)
        kv_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)) < sk
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(q_start, k_start, s.shape, causal=causal,
                           window=window, sk=sk)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dv_acc[...] = dv_acc[...] + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = jnp.where(mask, p * (dp - delta), 0.0)
        dk_acc[...] = dk_acc[...] + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _fin():
        dk_ref[0, 0] = (dk_acc[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_call(q, k, v, o, lse, do, *, causal, window, scale, block_q, block_k,
              interpret):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)

    # delta = rowsum(dO ∘ O): one elementwise pass, no attention recompute
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    lse4 = lse[..., None]                      # (B, Hq, Sq, 1) f32
    delta4 = delta[..., None]

    common = dict(scale=scale, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, sq=sq, sk=sk)
    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda b, h, iq, ik, g=group: (b, h // g, ik, 0))
    row_spec = pl.BlockSpec((1, 1, block_q, 1),
                            lambda b, h, iq, ik: (b, h, iq, 0))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(b, hq, n_q, n_k),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, do, lse4, delta4)

    # dK/dV: each kv head walks its whole query group (G heads × n_q blocks)
    # on the sequential axis, accumulating in scratch — GQA sums in VMEM
    def qmap(b, h, jk, t, g=group, nq=n_q):
        return (b, h * g + t // nq, t % nq, 0)

    qg_spec = pl.BlockSpec((1, 1, block_q, d), qmap)
    rowg_spec = pl.BlockSpec((1, 1, block_q, 1), qmap)
    kvg_spec = pl.BlockSpec((1, 1, block_k, d),
                            lambda b, h, jk, t: (b, h, jk, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common, n_q=n_q),
        grid=(b, hkv, n_k, group * n_q),
        in_specs=[qg_spec, kvg_spec, kvg_spec, qg_spec, rowg_spec, rowg_spec],
        out_specs=[kvg_spec, kvg_spec],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, do, lse4, delta4)
    return dq, dk, dv


# --------------------------------------------------------------------------- #
# custom_vjp plumbing + public entry
# --------------------------------------------------------------------------- #


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, scale, block_q, block_k, interpret):
    return _fwd_call(q, k, v, causal=causal, window=window, scale=scale,
                     block_q=block_q, block_k=block_k, interpret=interpret)[0]


def _flash_fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    o, lse = _fwd_call(q, k, v, causal=causal, window=window, scale=scale,
                       block_q=block_q, block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, scale, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    return _bwd_call(q, k, v, o, lse, do, causal=causal, window=window,
                     scale=scale, block_q=block_q, block_k=block_k,
                     interpret=interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Blockwise attention, differentiable. q (B,Hq,Sq,D); k,v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    if scale is None:
        scale = d ** -0.5
    return _flash(q, k, v, causal, window, float(scale), int(block_q),
                  int(block_k), bool(interpret))
