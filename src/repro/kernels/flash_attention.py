"""Pallas TPU flash attention (forward) — blockwise online softmax.

Used by the serving/prefill path on real TPUs (the dry-run and CPU tests use
the pure-jnp chunked oracle; see models/layers.py `attention_impl`).

Layout: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D) with GQA group = Hq // Hkv
resolved inside the BlockSpec index maps (no kv repetition in HBM!).

Grid: (B, Hq, Sq/block_q, Sk/block_k) — the k axis is last (sequential on
TPU), carrying the running max/denominator/accumulator in VMEM scratch.
Causal/windowed blocks that are fully masked are skipped with pl.when — for
causal attention this halves the compute (matches FlashAttention-2 behaviour).

Alignment: block_q/block_k multiples of 128 (lane), head dim is the minor-most
axis of every tile; pad D to a multiple of 128 outside for peak MXU mapping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _COMPILER_PARAMS_CLS

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, sq: int, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = iq * block_q + (sk - sq)  # right-aligned absolute q positions
    k_start = ik * block_k

    # --- block-level culling (causal / window) -------------------------------
    run = True
    if causal:
        run = jnp.asarray(k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, jnp.asarray(k_start + block_k - 1 > q_start - window))
    if not causal and window is None:
        run = jnp.asarray(True)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        # zero the ragged k/v tail: p is 0 there, but 0 * pad-NaN would poison acc
        kv_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)) < sk
        v = jnp.where(kv_valid, v, 0.0)
        k = jnp.where(kv_valid, k, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < sk  # ragged tail
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0:1]                      # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # (block_q, block_k)
        corr = jnp.exp(m_prev - m_new)              # (block_q, 1)
        l_new = corr * l_ref[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _fin():
        l = l_ref[:, 0:1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Blockwise attention forward. q (B,Hq,Sq,D); k,v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (b, hq, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, sk=sk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
