"""Tile/grid planning for generated projection kernels (DESIGN.md §4).

The lowering (``lowering.py``) tiles the *canonical* view of a compiled
schedule — ``Schedule.canonical_shape = (g_1, …, g_{L-1}, m)`` where g_t is
the aggregated extent of reduce level t and m the flattened surviving axes —
with the layout the hand-written golden kernels proved out:

* the lane axis is ``m`` (the solve axis), blocked by ``block_m`` and walked
  by a PARALLEL grid dimension;
* the sublane axis is ``g_{L-1}`` (the *last* reduced axis), blocked by
  ``block_n`` and walked by the SEQUENTIAL (``arbitrary``) grid dimension —
  the only reduce that crosses grid steps accumulates over it;
* every earlier reduced axis ``g_1 … g_{L-2}`` stays fully VMEM-resident in
  the tile (experts/heads/slices: small in every assigned architecture).

One rule forces full residency of the sublane axis: an ℓ1 ApplyGroup needs
its whole group for the per-group θ-solve, so when level L-1 (whose group
runs along ``g_{L-1}``) is ℓ1 the axis cannot be split across sequential
blocks — ``plan_tiles`` then pins ``block_n = g_{L-1}`` and lets the VMEM
check decide eligibility. ℓ∞/ℓ2 applies are elementwise given the solved
radii (and the saved *global* final aggregate), so they split freely.

``plan_tiles`` returns ``None`` when no block assignment fits the VMEM
budget — the planner backend's ``available()`` gate, which routes the design
back to the jnp schedule executor.

``plan_tiles``'s default block sizes are heuristics, not measurements.
``candidate_tile_plans`` enumerates the small measured-search grid around the
default (halved/doubled block sizes, VMEM-filtered, the ℓ1 residency pin
respected) that ``kernels.codegen.autotune_tiles`` shoots out the same way
``method="auto"`` shoots out planner backends — the winner is cached per
(canonical shape, dtype, device, interpret).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core.schedule import Schedule

DEFAULT_BLOCK_N = 256       # sublane-axis rows per tile
DEFAULT_BLOCK_M = 512       # lane-axis columns per tile
MIN_BLOCK_N = 8             # f32 sublane granule
MIN_BLOCK_M = 128           # lane granule

# per-step VMEM residency ceiling (~half a 16 MB core: leave the compiler
# slack for double buffering and the θ-solve stage)
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


class TilePlan(NamedTuple):
    """Grid/block assignment for one compiled schedule (batch axes excluded).

    ``canon_shape`` is the collapsed ``(g_1, …, g_{L-1}, m)`` view the
    kernels operate on; ``lead`` its VMEM-resident prefix ``(g_1 … g_{L-2})``;
    ``n``/``m`` the two gridded extents (sequential sublane / parallel lane);
    ``n_resident`` records that the whole sublane axis sits in one block
    (required for an ℓ1 apply over it); ``vmem_bytes`` the estimated
    double-buffered per-step residency the budget was checked against.
    """

    canon_shape: Tuple[int, ...]
    lead: Tuple[int, ...]
    n: int
    m: int
    block_n: int
    block_m: int
    n_resident: bool
    vmem_bytes: int


def _tile_bytes(lead: Tuple[int, ...], block_n: int, block_m: int,
                itemsize: int) -> int:
    """Worst-case per-grid-step VMEM residency of the generated kernels.

    The apply pass is the high-water mark: the y tile, the output tile, one
    tile per intermediate aggregate (suffix products of ``lead``), and the
    two (1, block_m) rows; ×2 for pipelined double buffering.
    """
    lead_elems = math.prod(lead) if lead else 1
    elems = 2 * lead_elems * block_n * block_m          # y tile + out tile
    suffix = 1
    for g in reversed(lead):                            # aggregate v_t tiles
        elems += suffix * block_n * block_m
        suffix *= g
    elems += 2 * block_m                                # v-final + u rows
    return 2 * elems * itemsize


class BatchedTilePlan(NamedTuple):
    """Grid/block assignment for a serving bucket: ``batch`` stacked items.

    ``base`` is the per-item :class:`TilePlan`; the generated batched kernels
    prepend the batch extent as the LEADING (parallel) Pallas grid dimension,
    so one dispatch walks ``batch × grid(base)`` programs with per-item radii
    block-sliced from SMEM by the batch grid index. Per-grid-step VMEM
    residency equals the per-item plan's (the batch block size is 1), so the
    budget check is the base plan's check.
    """

    base: TilePlan
    batch: int

    @property
    def grid_prefix(self) -> Tuple[int, ...]:
        return (self.batch,)


def plan_batched_tiles(sched: Schedule, dtype, batch: int) -> Optional[BatchedTilePlan]:
    """Pick the batched-grid assignment for ``batch`` stacked instances of
    ``sched``, or ``None`` when the per-item design cannot be generated.

    ``sched`` is the batch-free per-item schedule (the serving plan's
    ``key.shape``); the batch axis never enters the schedule because items do
    not share aggregates — it is purely a grid dimension.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    base = plan_tiles(sched, dtype)
    if base is None:
        return None
    return BatchedTilePlan(base, int(batch))


def plan_tiles(sched: Schedule, dtype) -> Optional[TilePlan]:
    """Pick VMEM-resident block sizes for ``sched``, or ``None`` if the
    design cannot be generated (flat non-ℓ1 solve, or no fitting blocks)."""
    if sched.batch_dims:
        raise ValueError(
            "plan_tiles takes a batch-free schedule; the generator strips "
            "batch axes (vmap) before tiling")
    dims = sched.canonical_shape
    itemsize = np.dtype(dtype).itemsize
    if len(sched.levels) == 1:
        # Prop 6.3 degenerate case: the whole design IS the outer solve.
        # Only l1 has a VMEM θ-solver kernel worth generating.
        if sched.solve.norm != "1":
            return None
        m = dims[-1]
        return TilePlan(dims, (), 1, m, 1, m, True, m * itemsize)
    lead, n, m = dims[:-2], dims[-2], dims[-1]
    # an l1 apply over the sequential axis needs its whole group in one block
    n_resident = sched.levels[-2][0] == "1"
    block_n = n if n_resident else min(DEFAULT_BLOCK_N, max(MIN_BLOCK_N, n))
    block_m = min(DEFAULT_BLOCK_M, max(MIN_BLOCK_M, m))
    while _tile_bytes(lead, block_n, block_m, itemsize) > VMEM_BUDGET_BYTES:
        if not n_resident and block_n > MIN_BLOCK_N:
            block_n = max(MIN_BLOCK_N, block_n // 2)
        elif block_m > MIN_BLOCK_M:
            block_m = max(MIN_BLOCK_M, block_m // 2)
        else:
            return None
    return TilePlan(dims, lead, n, m, block_n, block_m, n_resident,
                    _tile_bytes(lead, block_n, block_m, itemsize))


def candidate_tile_plans(sched: Schedule, dtype) -> Tuple[TilePlan, ...]:
    """The measured-search grid for one schedule: the default plan first, then
    every VMEM-fitting neighbor with halved/doubled block sizes.

    The grid is deliberately small (≤ 9 plans): the autotuner times each
    candidate's full fused pipeline, so the search must stay cheap enough to
    run at plan-build time. An ℓ1 apply over the sublane axis keeps its
    residency pin (``block_n = n`` is the only legal choice there), so those
    designs search ``block_m`` only. Returns ``()`` when the design cannot be
    generated at all, and a single plan for the degenerate flat solve.
    """
    default = plan_tiles(sched, dtype)
    if default is None:
        return ()
    if len(sched.levels) == 1:
        return (default,)
    itemsize = np.dtype(dtype).itemsize
    if default.n_resident:
        ns = (default.block_n,)
    else:
        ns = {default.block_n,
              max(MIN_BLOCK_N, default.block_n // 2),
              min(max(MIN_BLOCK_N, default.n), default.block_n * 2)}
    ms = {default.block_m,
          max(MIN_BLOCK_M, default.block_m // 2),
          min(max(MIN_BLOCK_M, default.m), default.block_m * 2)}
    plans = [default]
    for bn in sorted(ns):
        for bm in sorted(ms):
            vb = _tile_bytes(default.lead, bn, bm, itemsize)
            if vb > VMEM_BUDGET_BYTES:
                continue
            tp = TilePlan(default.canon_shape, default.lead, default.n,
                          default.m, bn, bm, default.n_resident, vb)
            if tp not in plans:
                plans.append(tp)
    return tuple(plans)
