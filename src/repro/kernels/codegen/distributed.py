"""Sharded codegen lowering — fused Pallas shard-local stages in shard_map.

The mesh executor (``core/sharded.py``) runs a compiled schedule as local
stages stitched by DESIGN.md §3's collective plan: one psum/pmax combine per
sharded ReduceLevel, a tiny all-gather + replicated θ-solve + re-slice for
the OuterSolve, local applies (with a distributed bisection for a
mesh-spanning ℓ1 group). Its local stages are plain jnp. This module builds
the *same* body with the local stages lowered through ``kernels/codegen``:

* the shard's reduce sweep is ONE streaming Pallas pass (``_reduce_call`` on
  the local schedule's tile plan), producing every intermediate aggregate and
  the final level's RAW accumulator;
* when the final reduce level spans the mesh, its combine splices between the
  kernels on the raw accumulator (psum for ℓ1/ℓ2 — ℓ2 accumulates squares —
  pmax for ℓ∞) BEFORE the monoid's finalize, so the collective payload is
  exactly the jnp body's (the already-reduced aggregate);
* the OuterSolve gathers the finalized aggregate over surviving sharded axes
  in the *uncollapsed* surviving-axes view, solves replicated with the
  codegen θ-solve, and slices the local radii back out — the jnp body's plan
  verbatim;
* the apply sweep is ONE fused Pallas epilogue (``_apply_call``) — unless the
  final level is an ℓ1 whose group spans the mesh, in which case the
  distributed bisection (``core.sharded._grouped_l1_collective``) runs on the
  last intermediate aggregate and the epilogue *resumes* one level down
  (``_partial_apply_call``).

The collective sequence is identical to the jnp body's by construction —
``sharded_collective_bytes`` is a function of (schedule, spec) alone, and the
equality tests assert the traced collective primitives match.

Eligibility (:func:`shardable`): a sharded tensor axis must be a batch axis,
a surviving (solve) axis, or an axis of the FINAL reduce level. An axis of an
*intermediate* reduce level folds inside the reduce mega-kernel's VMEM tile —
there is no splice point for its combine — so those designs stay on the jnp
body. The local (per-shard) schedule must also tile (``plan_tiles``).
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import schedule as sched_mod
from repro.core.schedule import Schedule
from repro.obs import profile as obs_profile

from . import autotune_tiles
from .lowering import (MONOIDS, _apply_call, _partial_apply_call,
                       _reduce_call, _solve_outer_vec)
from .tiling import TilePlan, plan_tiles


def _level_of_axis(levels, batch_dims: int, axis: int) -> int:
    """The (0-indexed) level owning tensor axis ``axis``; levels consume
    contiguous axis runs left to right after the batch prefix."""
    off = batch_dims
    for t, (_, k) in enumerate(levels):
        if axis < off + k:
            return t
        off += k
    raise ValueError(f"axis {axis} not covered by levels {levels}")


def local_shape(shape: Sequence[int], axis_names: Sequence[Optional[str]],
                mesh) -> Tuple[int, ...]:
    """Per-shard shape of ``shape`` under ``axis_names`` — ceil division, to
    match the executor's zero-padding of uneven shards."""
    return tuple(-(-d // mesh.shape[n]) if n else d
                 for d, n in zip(shape, axis_names))


def shardable(shape, levels, axis_names: Sequence[Optional[str]], mesh,
              dtype, batch_dims: int = 0) -> bool:
    """Can this design's shard-local stages lower through codegen?

    False when an *intermediate* reduce level's axis is sharded (its fold is
    in-tile — no splice point for the combine) or when the local per-shard
    schedule has no VMEM-resident tiling.
    """
    levels = sched_mod.canonical_levels(levels)
    L = len(levels)
    b = batch_dims
    for a, n in enumerate(axis_names):
        if n is None or a < b:
            continue
        if _level_of_axis(levels, b, a) < L - 2:
            return False
    lshape = local_shape(shape, axis_names, mesh)
    lsched = sched_mod.compile_schedule(lshape[b:], levels)
    return plan_tiles(lsched, dtype) is not None


def make_codegen_schedule_body(sched: Schedule,
                               axis_names: Sequence[Optional[str]], mesh,
                               dtype, *, method: str = "bisect",
                               interpret: bool = False,
                               tile_plan: Optional[TilePlan] = None,
                               measure: Optional[bool] = None) -> Callable:
    """Build the shard_map body ``(y_local, radius) -> x_local`` with the
    shard-local stages lowered through the fused Pallas kernels.

    ``sched`` is the GLOBAL schedule on the (padded, evenly-divisible) shape;
    the local schedule and its tile plan derive from the per-shard shape.
    ``tile_plan`` overrides the block sizes; by default the measured
    autotuner picks them on the local workload (``measure`` as in
    :func:`repro.kernels.codegen.autotune_tiles`). Leading batch axes vmap
    the batch-free body — collectives batch through vmap unchanged.

    Gate with :func:`shardable` first; raises ``ValueError`` when the design
    has no codegen lowering on this mesh.
    """
    from repro.core.sharded import _grouped_l1_collective

    b = sched.batch_dims
    levels = sched.levels
    L = len(levels)
    names = tuple(axis_names)
    if not shardable(sched.shape, levels, names, mesh, dtype, b):
        raise ValueError(
            f"no sharded codegen lowering for levels={levels} on "
            f"shape={sched.shape} with axes {names}: an intermediate reduce "
            "axis is sharded, or the local shard does not tile")
    lshape = local_shape(sched.shape, names, mesh)
    if any(d % mesh.shape[n] for d, n in zip(sched.shape, names) if n):
        raise ValueError(
            "make_codegen_schedule_body needs even shards — the executor "
            "zero-pads and recompiles before building the body")
    lsched = sched_mod.compile_schedule(lshape[b:], levels)
    norms = [q for q, _ in levels]
    if tile_plan is None:
        tile_plan = autotune_tiles(lshape[b:], levels, dtype, method=method,
                                   interpret=interpret, measure=measure)
    tp = tile_plan if tile_plan is not None else plan_tiles(lsched, dtype)

    # final reduce level (index L-2): mesh axes its combine spans. Levels
    # consume contiguous ORIGINAL-tensor axis runs left to right (ReduceLevel
    # .axes are stage-relative, so recompute the original run here).
    n_reduced = sum(k for _, k in levels[:-1])
    n_before_fin = sum(k for _, k in levels[:-2])
    fin_coll = tuple(names[a] for a in range(b + n_before_fin, b + n_reduced)
                     if names[a]) if L > 1 else ()
    # surviving (solve) axes: the last level's run — gather/slice positions
    # are relative to the batch-free reduced tensor (stage_shapes[-1])
    surv_names = names[b + n_reduced:]
    surv_loc = lsched.stage_shapes[-1]
    surv_glob = tuple(d * mesh.shape[n] if n else d
                      for d, n in zip(surv_loc, surv_names))

    def _gather(g):
        for ax, n in enumerate(surv_names):
            if n:
                g = jax.lax.all_gather(g, n, axis=ax, tiled=True)
        return g

    def _slice_back(w):
        for ax, n in enumerate(surv_names):
            if n:
                idx = jax.lax.axis_index(n)
                w = jax.lax.dynamic_slice_in_dim(
                    w, idx * surv_loc[ax], surv_loc[ax], axis=ax)
        return w

    def _solve_sliced(v, norm, radius):
        """Replicated outer solve with the surviving-axes gather/re-slice."""
        if not any(surv_names):
            return _solve_outer_vec(v, norm, radius, method, interpret)
        g = _gather(v.reshape(surv_loc))
        u = _solve_outer_vec(g.reshape(-1), norm, radius, method, interpret)
        return _slice_back(u.reshape(surv_glob)).reshape(v.shape)

    def inner(y, radius):
        if L == 1:
            # degenerate flat solve: the whole design IS the OuterSolve
            with obs_profile.scope(f"codegen_solve_{norms[0]}"):
                return _solve_sliced(y.reshape(-1), norms[0],
                                     radius).reshape(y.shape)
        yc = y.reshape(tp.canon_shape)
        with obs_profile.scope("codegen_partial_reduce"):
            aggs, acc = _reduce_call(yc, tp, norms[:-1], interpret)
            if fin_coll:
                # splice the final level's combine on the RAW accumulator (ℓ2
                # is still in the squared domain here), then finalize
                acc = jax.lax.pmax(acc, fin_coll) if norms[-2] == "inf" \
                    else jax.lax.psum(acc, fin_coll)
            vfin = MONOIDS[norms[-2]].finalize(acc)
        with obs_profile.scope(f"codegen_solve_{norms[-1]}"):
            u = _solve_sliced(vfin, norms[-1], radius)
        with obs_profile.scope("codegen_apply"):
            if norms[-2] == "1" and fin_coll:
                # the final level's ℓ1 groups span the mesh: distributed
                # θ-solve on the last resident stage, then resume the
                # epilogue below it
                src = yc if L == 2 else aggs[-1]
                w = _grouped_l1_collective(src, u, (0,), fin_coll, vfin)
                x = w if L == 2 else _partial_apply_call(yc, aggs, w, tp,
                                                         norms[:-1],
                                                         interpret)
            else:
                x = _apply_call(yc, aggs, vfin, u, tp, norms[:-1], interpret)
        return x.reshape(y.shape)

    fn = inner
    for _ in range(b):
        fn = jax.vmap(fn, in_axes=(0, None))

    def body(y_loc, radius):
        return fn(y_loc, jnp.asarray(radius, y_loc.dtype))

    return body
