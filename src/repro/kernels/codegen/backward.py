"""Generated backward for compiled schedules — residual VJPs, no re-execution.

The forward of a compiled schedule (DESIGN.md §4) is

    ReduceLevel* → OuterSolve → ApplyGroup*

and its Jacobian factors stage-by-stage into pieces that are *diagonal plus
rank-one per group*:

* a **reduce** VJP is an elementwise expansion of the aggregate cotangent
  (``sign(s)`` for ℓ1, ``s/‖s‖`` for ℓ2, an even tie-split at the max for ℓ∞
  — exactly the subgradient JAX's autodiff picks);
* the **outer-solve** VJP is the classic projection Jacobian: identity inside
  the ball; outside it ``diag(1_S) − rank-one over the active set S`` (ℓ1),
  ``(r/‖v‖)(I − v̂v̂ᵀ)`` (ℓ2), a clip mask (ℓ∞) — with S read off the *saved*
  solved output, never re-solved;
* an **apply** VJP is the grouped version of the same three forms, with the
  "group untouched" indicator read from the SAVED forward aggregate of the
  same level (the apply norm at stage t equals the reduce norm at stage t, so
  the group norm is already a residual — no second reduce over ``y``).

The residuals are what the forward pipeline already materializes: ``y``, every
finalized stage aggregate ``s_1 … s_{L-1}``, the solved radii ``u``, the
projected output ``x``, and ``radius``. The only recomputation is the
intermediate radii chain (the apply outputs *above* stage 0), which lives on
aggregate-sized tensors — O(Σ aggregate sizes), never O(y) — so the backward
is one streaming elementwise pass over (y, x, g) plus the per-group cotangent
reductions the rank-one terms require. ``schedule.execute`` (the sort-oracle
recompute the old custom-vjp used) is never called; the grad-parity matrix in
``tests/test_codegen_backward.py`` pins this VJP against it at 1e-5 while
stubbing the executor out.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ball


def _finv(x):
    """1/x that is 0 at 0 (used only where a `shrink` mask already gates)."""
    return jnp.where(x == 0, 0.0, 1.0 / jnp.where(x == 0, 1.0, x))


# --------------------------------------------------------------------------- #
# Per-stage VJPs (group axis = axis 0, canonical layout)
# --------------------------------------------------------------------------- #


def reduce_vjp(q: str, s: jax.Array, v: jax.Array, c: jax.Array) -> jax.Array:
    """Cotangent of ``s`` given cotangent ``c`` of ``v = norm_reduce(s, q, 0)``.

    ``v``/``c`` have ``s.shape[1:]``. Elementwise in ``s`` given the saved
    aggregate — no reduction happens here.
    """
    if q == "1":
        return c[None] * jnp.sign(s)
    if q == "2":
        return (c * _finv(v))[None] * s
    a = jnp.abs(s)
    ties = a == v[None]
    share = c * _finv(jnp.sum(ties, axis=0).astype(s.dtype))
    return jnp.where(ties, share[None] * jnp.sign(s), 0.0)


def apply_vjp(q: str, s: jax.Array, w: jax.Array, agg: jax.Array,
              out: jax.Array, g: jax.Array):
    """VJP of ``out = apply_group(s, q, radii=w, axes=(0,), agg=agg)``.

    ``w``/``agg`` have ``s.shape[1:]``; returns ``(ds, dw, dagg)`` with
    ``dagg`` None unless ``q == '2'`` (the only apply that *reads* its saved
    aggregate in the forward — its rescale differentiates through it).
    """
    if q == "inf":
        inside = jnp.abs(s) < w[None]
        ds = jnp.where(inside, g, 0.0)
        dw = jnp.sum(jnp.where(inside, 0.0, g * jnp.sign(s)), axis=0)
        return ds, dw, None
    if q == "2":
        shrink = agg > w
        inv = _finv(jnp.maximum(agg, 1e-30))
        ds = g * jnp.where(shrink, w * inv, 1.0)[None]
        gs = jnp.sum(g * s, axis=0)          # cotangent of the scale
        dw = jnp.where(shrink, gs * inv, 0.0)
        dagg = jnp.where(shrink, -gs * w * inv * inv, 0.0)
        return ds, dw, dagg
    # l1 — `agg` IS the saved group norm sum|s| (same-level reduce), so the
    # untouched-group test is a residual read, and the active set comes off
    # the saved output values
    inside = (agg <= w)[None]
    act = out != 0.0
    cnt = jnp.maximum(jnp.sum(act, axis=0), 1).astype(s.dtype)
    sg = jnp.sign(s)
    sigma = jnp.sum(jnp.where(act, sg * g, 0.0), axis=0)
    corr = (sigma / cnt)[None]
    ds = jnp.where(inside, g, jnp.where(act, g - sg * corr, 0.0))
    dw = jnp.where(inside[0], 0.0, sigma / cnt)
    return ds, dw, None


def outer_vjp(q: str, v: jax.Array, u: jax.Array, radius, du: jax.Array):
    """VJP of the OuterSolve ``u = project_ball(v, q, radius)`` on the flat
    (m,) aggregate. Returns ``(dv, dradius)`` (dradius a scalar)."""
    du = du.reshape(v.shape)
    if q == "inf":
        inside = jnp.abs(v) < radius
        dv = jnp.where(inside, du, 0.0)
        dr = jnp.sum(jnp.where(inside, 0.0, du * jnp.sign(v)))
        return dv, dr
    if q == "2":
        nrm = jnp.sqrt(jnp.sum(v * v))
        shrink = nrm > radius
        inv = _finv(jnp.maximum(nrm, 1e-30))
        vhat = v * inv
        vg = jnp.sum(vhat * du)
        dv = jnp.where(shrink, radius * inv * (du - vhat * vg), du)
        dr = jnp.where(shrink, vg, 0.0)
        return dv, dr
    inside = jnp.sum(jnp.abs(v)) <= radius
    act = u.reshape(v.shape) != 0.0
    cnt = jnp.maximum(jnp.sum(act), 1).astype(v.dtype)
    sg = jnp.sign(v)
    sigma = jnp.sum(jnp.where(act, sg * du, 0.0))
    dv = jnp.where(inside, du, jnp.where(act, du - sg * sigma / cnt, 0.0))
    dr = jnp.where(inside, 0.0, sigma / cnt)
    return dv, dr


# --------------------------------------------------------------------------- #
# The full-schedule VJP on canonical-shape residuals
# --------------------------------------------------------------------------- #


def _apply_forward(q: str, s: jax.Array, w: jax.Array,
                   agg: jax.Array) -> jax.Array:
    """One apply step on an aggregate-sized stage (radii-chain recompute)."""
    if q == "inf":
        return jnp.clip(s, -w[None], w[None])
    if q == "2":
        scale = jnp.where(agg > w, w / jnp.maximum(agg, 1e-30), 1.0)
        return s * scale[None]
    return ball.project_grouped(s, "1", w, inner_axes=(0,), method="sort")


def schedule_vjp(norms: Sequence[str], stages: Sequence[jax.Array],
                 u: jax.Array, x: jax.Array, radius,
                 g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The generated VJP of one compiled schedule, from residuals only.

    ``norms = [q_1 … q_L]``; ``stages = [s_0=y, s_1, …, s_{L-1}]`` in the
    canonical ``(g_1, …, g_{L-1}, m)`` layout (``s_{L-1}`` flat ``(m,)``);
    ``u`` the OuterSolve output; ``x`` the projected output (canonical);
    ``g`` the output cotangent (canonical). Returns ``(dy, dradius)`` with
    ``dy`` canonical. Never calls ``schedule.execute`` or any θ-solver on a
    y-sized tensor.
    """
    L = len(norms)
    if L == 1:
        return outer_vjp(norms[0], stages[0], x, radius, g)

    # the radii chain A_i = apply-output at stage i; A_0 = x is saved, the
    # rest (aggregate-sized) replays down from the solved u
    A = [None] * (L - 1)
    A[0] = x
    W = [None] * (L - 1)            # W_i = radii consumed by stage i's apply
    W[L - 2] = u.reshape(stages[L - 2].shape[1:])
    for i in range(L - 2, 0, -1):
        A[i] = _apply_forward(norms[i], stages[i], W[i], stages[i + 1])
        W[i - 1] = A[i]

    c = [jnp.zeros_like(s) for s in stages]   # stage cotangent accumulators
    gi = g
    for i in range(L - 1):
        ds, dw, dagg = apply_vjp(norms[i], stages[i], W[i], stages[i + 1],
                                 A[i], gi)
        c[i] = c[i] + ds
        if dagg is not None:
            c[i + 1] = c[i + 1] + dagg
        gi = dw                                # cotangent of A_{i+1} (or u)
    dv, dr = outer_vjp(norms[-1], stages[-1], u, radius, gi)
    c[L - 1] = c[L - 1] + dv
    for t in range(L - 1, 0, -1):
        c[t - 1] = c[t - 1] + reduce_vjp(norms[t - 1], stages[t - 1],
                                         stages[t], c[t])
    return c[0], dr
