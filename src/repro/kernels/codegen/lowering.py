"""Schedule-IR → fused Pallas kernel lowering (DESIGN.md §4).

A compiled ``Schedule`` is ``ReduceLevel* → OuterSolve → ApplyGroup*``. The
generator lowers that to the same three-stage structure the hand-written
golden kernels (``bilevel_l1inf.py`` / ``trilevel_l1infinf.py``) use, but for
*any* norm design the tiler accepts:

* **reduce mega-kernel** — ONE streaming pass over Y produces every forward
  aggregate: each intermediate ``ReduceLevel`` folds its (VMEM-resident) axis
  with the norm's monoid inside the tile, and the final level accumulates
  across the sequential grid axis (``max`` for ℓ∞, ``add`` for ℓ1, ``add`` of
  squares for ℓ2 — finalized after the pass). Y is read exactly once here.
* **outer stage** — the tiny θ-solve on the (m,)-vector: the VPU-shaped
  bisect/filter VMEM kernels from ``kernels/l1ball.py`` for an ℓ1 solve
  (jnp fallback past the single-block limit or for ``method="sort"``), a
  rescale/clip for ℓ2/ℓ∞.
* **apply epilogue** — ONE elementwise pass over Y replays the backward
  sweep per tile: the radii chain starts at the solved aggregate and walks
  down through the saved per-tile aggregates (ℓ∞ → clip, ℓ2 → rescale by the
  saved *global* aggregate, ℓ1 → an in-tile batched bisection θ-solve per
  group), writing X. Y is read exactly twice end-to-end — the same
  information-theoretic minimum as the golden kernels.

Reverse-mode: generated kernels carry a ``custom_vjp`` whose backward is the
*generated* residual VJP (``backward.py``): the forward pipeline already
materializes every stage aggregate, the solved radii, and the projected
output, so the backward is one streaming elementwise+group-reduction pass
over (y, x, g) — the apply Jacobians are diagonal-plus-rank-one per group —
with the tiny radii chain replayed on aggregate-sized tensors. No sort-oracle
recompute, no ``schedule.execute`` call, no second reduce over ``y``.

Serving buckets (B stacked items, per-item radii) lower through
:func:`generate_batched` instead: the batch axis joins the Pallas grid as its
leading parallel dimension and the per-item radii ride in SMEM for the
θ-solve stage (DESIGN.md §5) — one dispatch per pipeline stage for the whole
bucket, versus one vmap-lifted kernel per stage per item.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ball, schedule as sched_mod
from repro.core.schedule import Schedule
from repro.obs import profile as obs_profile

from .._compat import CompilerParams
from . import backward as bwd_mod
from .tiling import TilePlan, plan_tiles

_GROUP_SOLVE_ITERS = 64  # in-tile grouped θ-solves: fixed-budget bisection


class Monoid(NamedTuple):
    """In-VMEM staged reduction for one norm, on non-negative inputs.

    ``tile`` folds an axis inside one tile and finalizes (what intermediate
    reduces use); ``part``/``combine``/``finalize`` split the same reduction
    into a raw per-block accumulator + cross-grid-step combine + a post-pass
    finalizer (what the sequential-axis reduce uses: ℓ2 accumulates in the
    squared domain, so its finalize is the √ applied after the last step).
    """

    tile: Callable[[jax.Array, int], jax.Array]
    part: Callable[[jax.Array, int], jax.Array]
    combine: Callable[[jax.Array, jax.Array], jax.Array]
    finalize: Callable[[jax.Array], jax.Array]


MONOIDS = {
    "1": Monoid(
        tile=lambda a, ax: jnp.sum(a, axis=ax),
        part=lambda a, ax: jnp.sum(a, axis=ax),
        combine=jnp.add,
        finalize=lambda acc: acc,
    ),
    "2": Monoid(
        tile=lambda a, ax: jnp.sqrt(jnp.sum(a * a, axis=ax)),
        part=lambda a, ax: jnp.sum(a * a, axis=ax),
        combine=jnp.add,
        finalize=jnp.sqrt,
    ),
    "inf": Monoid(
        tile=lambda a, ax: jnp.max(a, axis=ax),
        part=lambda a, ax: jnp.max(a, axis=ax),
        combine=jnp.maximum,
        finalize=lambda acc: acc,
    ),
}


def _grouped_l1_tile(x: jax.Array, radii_b: jax.Array,
                     iters: int = _GROUP_SOLVE_ITERS) -> jax.Array:
    """Project every axis-0 slice of ``x`` onto its own ℓ1 ball, in-tile.

    ``radii_b`` broadcasts against ``x`` with a size-1 group axis. Batched
    bisection on θ — elementwise ops + axis-0 reductions only, so it stays
    VPU-shaped whatever the surrounding tile shape is.
    """
    a = jnp.abs(x)
    hi = jnp.max(a, axis=0, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - mid, 0.0), axis=0, keepdims=True)
        too_small = phi > radii_b
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    inside = jnp.sum(a, axis=0, keepdims=True) <= radii_b
    theta = jnp.where(inside, jnp.zeros_like(lo), 0.5 * (lo + hi))
    return jnp.sign(x) * jnp.maximum(a - theta, 0.0)


# --------------------------------------------------------------------------- #
# Reduce mega-kernel
# --------------------------------------------------------------------------- #


def _make_reduce_kernel(norms: Sequence[str], n_total: int, block_n: int):
    """Kernel body: every forward aggregate of the schedule in one pass.

    ``norms`` are the reduce norms q_1 … q_{L-1}. Outputs are
    ``[v_1, …, v_{L-2}, acc]`` where v_t keeps the (block_n, block_m) tile
    structure and ``acc`` is the raw (1, block_m) accumulator of the final
    level, combined across sequential grid steps.
    """
    inter, last = tuple(norms[:-1]), norms[-1]

    def kernel(y_ref, *out_refs):
        i = pl.program_id(1)  # sequential row-block index (last grid axis)
        cur = jnp.abs(y_ref[...])
        for t, q in enumerate(inter):
            cur = MONOIDS[q].tile(cur, 0)   # fold the resident axis g_{t+1}
            out_refs[t][...] = cur
        # cur is (block_n, block_m): mask rows past the true edge with 0 —
        # the identity of every monoid here (values are non-negative)
        row_ids = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0) \
            + i * block_n
        cur = jnp.where(row_ids < n_total, cur, 0.0)
        part = MONOIDS[last].part(cur, 0)[None]          # (1, block_m)
        acc_ref = out_refs[-1]

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = part

        @pl.when(i > 0)
        def _acc():
            acc_ref[...] = MONOIDS[last].combine(acc_ref[...], part)

    return kernel


def _y_spec(tp: TilePlan):
    k = len(tp.lead)
    return pl.BlockSpec(tp.lead + (tp.block_n, tp.block_m),
                        lambda j, i, k=k: (0,) * k + (i, j))


def _agg_specs_shapes(tp: TilePlan, dtype):
    """BlockSpecs + ShapeDtypeStructs of the intermediate aggregates v_t."""
    specs, shapes = [], []
    for t in range(1, len(tp.lead) + 1):
        ld = tp.lead[t:]
        specs.append(pl.BlockSpec(ld + (tp.block_n, tp.block_m),
                                  lambda j, i, k=len(ld): (0,) * k + (i, j)))
        shapes.append(jax.ShapeDtypeStruct(ld + (tp.n, tp.m), dtype))
    return specs, shapes


def _row_spec(tp: TilePlan):
    return pl.BlockSpec((1, tp.block_m), lambda j, i: (0, j))


def _reduce_call(y: jax.Array, tp: TilePlan, norms: Sequence[str],
                 interpret: bool):
    grid = (pl.cdiv(tp.m, tp.block_m), pl.cdiv(tp.n, tp.block_n))
    agg_specs, agg_shapes = _agg_specs_shapes(tp, y.dtype)
    outs = pl.pallas_call(
        _make_reduce_kernel(norms, n_total=tp.n, block_n=tp.block_n),
        grid=grid,
        in_specs=[_y_spec(tp)],
        out_specs=agg_specs + [_row_spec(tp)],
        out_shape=agg_shapes + [jax.ShapeDtypeStruct((1, tp.m), y.dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(y)
    return list(outs[:-1]), outs[-1][0]   # ([v_1, …, v_{L-2}], raw acc (m,))


# --------------------------------------------------------------------------- #
# Apply epilogue
# --------------------------------------------------------------------------- #


def _apply_chain(norms: Sequence[str], stages, w):
    """Levels L-2 … 1 of the backward sweep on one resident tile.

    ``w`` is the radii tensor produced by the level-L-1 step (shaped like the
    last intermediate aggregate); the group axis of each step is the leading
    resident axis of its stage input, radii/aggregates live one stage up.
    Factored out of :func:`_apply_tile` so the sharded splice can resume the
    sweep here after a mesh-spanning level-L-1 ℓ1 apply ran collectively.
    """
    L = len(norms) + 1
    for lvl in range(L - 2, 0, -1):
        x, agg, q = stages[lvl - 1], stages[lvl], norms[lvl - 1]
        if q == "inf":
            w = jnp.clip(x, -w[None], w[None])
        elif q == "2":
            scale = jnp.where(agg > w, w / jnp.maximum(agg, 1e-30), 1.0)
            w = x * scale[None]
        else:
            w = _grouped_l1_tile(x, w[None])
    return w


def _apply_tile(norms: Sequence[str], stages, vfin, u):
    """The backward sweep on one resident tile (pure array form).

    ``stages`` are ``[y_tile, v_1, …, v_{L-2}]``; ``u`` the solved-aggregate
    row; ``vfin`` the saved global final aggregate (ℓ2 last reduce only). The
    radii chain ``w`` starts at the solved aggregate and walks levels L-1 → 1;
    every stage input it needs is a saved forward aggregate already resident
    in the tile. Shared by the single-item and batched-grid apply kernels.
    """
    # level L-1: its group runs along the sublane axis of the 2-D tile
    x, q, w = stages[-1], norms[-1], u
    if q == "inf":
        w = jnp.clip(x, -w, w)
    elif q == "2":
        scale = jnp.where(vfin > w, w / jnp.maximum(vfin, 1e-30), 1.0)
        w = x * scale
    else:  # "1" — tiling pinned the whole group axis into this block
        w = _grouped_l1_tile(x, w)
    return _apply_chain(norms, stages, w)


def _make_apply_kernel(norms: Sequence[str]):
    """Kernel body: the backward sweep fused into one elementwise pass.

    Inputs: ``y, v_1, …, v_{L-2}, [v_final_row,] u_row``; output: the
    projected tile (the final-aggregate row rides along only for an ℓ2 last
    reduce level, whose rescale needs the saved *global* norm).
    """
    L = len(norms) + 1
    has_vfin = norms[-1] == "2"

    def kernel(*refs):
        y_ref, v_refs = refs[0], refs[1:L - 1]
        vfin_ref = refs[L - 1] if has_vfin else None
        u_ref, out_ref = refs[-2], refs[-1]
        stages = [y_ref[...]] + [v[...] for v in v_refs]  # s_0 … s_{L-2}
        vfin = vfin_ref[...] if has_vfin else None
        out_ref[...] = _apply_tile(norms, stages, vfin, u_ref[...])

    return kernel


def _apply_call(y: jax.Array, aggs, vfin: jax.Array, u: jax.Array,
                tp: TilePlan, norms: Sequence[str], interpret: bool):
    grid = (pl.cdiv(tp.m, tp.block_m), pl.cdiv(tp.n, tp.block_n))
    agg_specs, _ = _agg_specs_shapes(tp, y.dtype)
    row = lambda v: v.reshape(1, tp.m).astype(y.dtype)  # noqa: E731
    rows = ([row(vfin)] if norms[-1] == "2" else []) + [row(u)]
    return pl.pallas_call(
        _make_apply_kernel(norms),
        grid=grid,
        in_specs=[_y_spec(tp)] + agg_specs
                 + [_row_spec(tp)] * len(rows),
        out_specs=_y_spec(tp),
        out_shape=jax.ShapeDtypeStruct(tp.canon_shape, y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, *aggs, *rows)


def _make_partial_apply_kernel(norms: Sequence[str]):
    """Apply epilogue that *resumes* at level L-2: the level-L-1 radii tensor
    ``w`` (shaped like the last intermediate aggregate v_{L-2}) arrives as an
    input instead of being computed in-tile — the sharded splice computed it
    with the distributed grouped-ℓ1 solve when level L-1 spans the mesh.

    Inputs: ``y, v_1, …, v_{L-2}, w``; output: the projected tile.
    """
    L = len(norms) + 1

    def kernel(*refs):
        y_ref, v_refs = refs[0], refs[1:L - 1]
        w_ref, out_ref = refs[-2], refs[-1]
        stages = [y_ref[...]] + [v[...] for v in v_refs]
        out_ref[...] = _apply_chain(norms, stages, w_ref[...])

    return kernel


def _partial_apply_call(y: jax.Array, aggs, w: jax.Array, tp: TilePlan,
                        norms: Sequence[str], interpret: bool):
    """Run the resumed apply epilogue; ``w`` is blocked exactly like the last
    intermediate aggregate (same BlockSpec as ``aggs[-1]``)."""
    grid = (pl.cdiv(tp.m, tp.block_m), pl.cdiv(tp.n, tp.block_n))
    agg_specs, _ = _agg_specs_shapes(tp, y.dtype)
    return pl.pallas_call(
        _make_partial_apply_kernel(norms),
        grid=grid,
        in_specs=[_y_spec(tp)] + agg_specs + [agg_specs[-1]],
        out_specs=_y_spec(tp),
        out_shape=jax.ShapeDtypeStruct(tp.canon_shape, y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, *aggs, w.astype(y.dtype))


# --------------------------------------------------------------------------- #
# Outer stage + the generator
# --------------------------------------------------------------------------- #


def _solve_outer_vec(v: jax.Array, norm: str, radius, method: str,
                     interpret: bool) -> jax.Array:
    """Project the finalized (m,) aggregate onto the outer ball."""
    if norm == "1":
        from ..l1ball import outer_l1_solve

        if ball.resolve_method(method) in ("bisect", "filter"):
            return outer_l1_solve(v, radius, method=method,
                                  interpret=interpret)
        return ball.project_l1(v, radius, method=method)
    if norm == "2":
        return ball.project_l2(v, radius)
    return jnp.minimum(v, jnp.asarray(radius, v.dtype))  # ℓ∞ on v ≥ 0


def _resolve_tile_plan(sched: Schedule, dtype,
                       tile_plan: TilePlan | None) -> TilePlan:
    """The generator's tiling: an explicit (autotuned) plan, validated against
    the schedule, or the heuristic default from :func:`plan_tiles`."""
    if tile_plan is not None:
        if tile_plan.canon_shape != sched.canonical_shape:
            raise ValueError(
                f"tile plan built for canonical shape {tile_plan.canon_shape} "
                f"cannot lower schedule with canonical shape "
                f"{sched.canonical_shape}")
        return tile_plan
    tp = plan_tiles(sched, dtype)
    if tp is None:
        raise ValueError(
            f"codegen cannot lower levels={sched.levels} on shape="
            f"{sched.shape}: no VMEM-resident tiling (or flat non-l1 solve)")
    return tp


def generate(sched: Schedule, dtype, *, method: str = "bisect",
             interpret: bool = False,
             tile_plan: TilePlan | None = None) -> Callable:
    """Compile ``sched`` into a fused ``(y, radius) -> x`` callable.

    ``method`` picks the *outer* θ-solve backend (the in-tile grouped solves
    are always the fixed-budget bisection — stable latency, VPU-shaped).
    Leading batch axes lower as vmaps of the batch-free kernel (the batch
    axes join the Pallas grid). ``tile_plan`` overrides the heuristic block
    sizes (the measured autotuner's winner). Raises ``ValueError`` when the
    tiler rejects the design — gate with :func:`tiling.plan_tiles` first.
    """
    if sched.batch_dims:
        base_sched = sched_mod.compile_schedule(
            sched.shape[sched.batch_dims:], sched.levels)
        fn = generate(base_sched, dtype, method=method, interpret=interpret,
                      tile_plan=tile_plan)
        for _ in range(sched.batch_dims):
            fn = jax.vmap(fn, in_axes=(0, None))
        return fn
    tp = _resolve_tile_plan(sched, dtype, tile_plan)
    norms = [q for q, _ in sched.levels]

    def raw(y, radius):
        """Forward pipeline; also returns the VJP residual aggregates."""
        yc = y.reshape(tp.canon_shape)
        if len(norms) == 1:
            with obs_profile.scope(f"codegen_solve_{norms[0]}"):
                out = _solve_outer_vec(yc, norms[0], radius, method,
                                       interpret)
            return out.reshape(y.shape), ()
        # the three lowering boundaries of the fused pipeline — one scope
        # each, so a captured trace attributes device time to the streaming
        # reduce pass, the VMEM θ-solve, and the fused apply epilogue
        with obs_profile.scope("codegen_reduce"):
            aggs, acc = _reduce_call(yc, tp, norms[:-1], interpret)
            vfin = MONOIDS[norms[-2]].finalize(acc)
        with obs_profile.scope(f"codegen_solve_{norms[-1]}"):
            u = _solve_outer_vec(vfin, norms[-1], radius, method, interpret)
        with obs_profile.scope("codegen_apply"):
            x = _apply_call(yc, aggs, vfin, u, tp, norms[:-1], interpret)
        return x.reshape(y.shape), (tuple(aggs), vfin, u)

    @jax.custom_vjp
    def fused(y, radius):
        return raw(y, radius)[0]

    def fwd(y, radius):
        x, internals = raw(y, radius)
        return x, (y, x, internals, radius)

    def bwd(res, g):
        # the generated residual VJP (backward.py): one streaming pass over
        # (y, x, g) + the aggregate-sized radii chain — the schedule executor
        # is NEVER re-run (tests stub it out to prove that)
        y, x, internals, radius = res
        yc = y.reshape(tp.canon_shape)
        gc = g.reshape(tp.canon_shape)
        if len(norms) == 1:
            stages = [yc]
            u = x.reshape(tp.canon_shape)
        else:
            aggs, vfin, u = internals
            stages = [yc, *aggs, vfin]
        dy, dr = bwd_mod.schedule_vjp(norms, stages, u,
                                      x.reshape(tp.canon_shape), radius, gc)
        return dy.reshape(y.shape), jnp.asarray(dr, y.dtype)

    fused.defvjp(fwd, bwd)

    @functools.wraps(fused)
    def entry(y, radius):
        y = jnp.asarray(y)
        return fused(y, jnp.asarray(radius, y.dtype))

    return entry


# --------------------------------------------------------------------------- #
# Batched-grid lowering (serving buckets)
# --------------------------------------------------------------------------- #
#
# A serving bucket is B stacked instances of ONE schedule with per-item radii.
# Items share no aggregates, so the batch axis never enters the schedule — it
# becomes the LEADING (parallel) Pallas grid dimension instead of a vmap
# around the batch-free kernel: one dispatch walks B × grid(base) programs,
# per-item rows/radii are block-sliced by the batch grid index (radii ride in
# SMEM for the θ-solve stage), and per-step VMEM residency stays the per-item
# plan's.


def _y_spec_batched(tp: TilePlan):
    k = len(tp.lead)
    return pl.BlockSpec((1,) + tp.lead + (tp.block_n, tp.block_m),
                        lambda b, j, i, k=k: (b,) + (0,) * k + (i, j))


def _agg_specs_shapes_batched(tp: TilePlan, dtype, batch: int):
    specs, shapes = [], []
    for t in range(1, len(tp.lead) + 1):
        ld = tp.lead[t:]
        specs.append(pl.BlockSpec(
            (1,) + ld + (tp.block_n, tp.block_m),
            lambda b, j, i, k=len(ld): (b,) + (0,) * k + (i, j)))
        shapes.append(jax.ShapeDtypeStruct((batch,) + ld + (tp.n, tp.m), dtype))
    return specs, shapes


def _row_spec_batched(tp: TilePlan):
    return pl.BlockSpec((1, 1, tp.block_m), lambda b, j, i: (b, 0, j))


def _make_batched_reduce_kernel(norms: Sequence[str], n_total: int,
                                block_n: int):
    """The reduce mega-kernel with the batch axis as grid dimension 0.

    Identical per-item math to :func:`_make_reduce_kernel`; every block gains
    a leading size-1 batch axis (squeezed on read, restored on write) and the
    sequential row-block index moves to ``program_id(2)``.
    """
    inter, last = tuple(norms[:-1]), norms[-1]

    def kernel(y_ref, *out_refs):
        i = pl.program_id(2)  # sequential row-block index (last grid axis)
        cur = jnp.abs(y_ref[...])[0]
        for t, q in enumerate(inter):
            cur = MONOIDS[q].tile(cur, 0)
            out_refs[t][...] = cur[None]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0) \
            + i * block_n
        cur = jnp.where(row_ids < n_total, cur, 0.0)
        part = MONOIDS[last].part(cur, 0)[None]          # (1, block_m)
        acc_ref = out_refs[-1]

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = part[None]

        @pl.when(i > 0)
        def _acc():
            acc_ref[...] = MONOIDS[last].combine(acc_ref[...], part[None])

    return kernel


def _reduce_call_batched(y: jax.Array, tp: TilePlan, norms: Sequence[str],
                         interpret: bool):
    batch = y.shape[0]
    grid = (batch, pl.cdiv(tp.m, tp.block_m), pl.cdiv(tp.n, tp.block_n))
    agg_specs, agg_shapes = _agg_specs_shapes_batched(tp, y.dtype, batch)
    outs = pl.pallas_call(
        _make_batched_reduce_kernel(norms, n_total=tp.n, block_n=tp.block_n),
        grid=grid,
        in_specs=[_y_spec_batched(tp)],
        out_specs=agg_specs + [_row_spec_batched(tp)],
        out_shape=agg_shapes
        + [jax.ShapeDtypeStruct((batch, 1, tp.m), y.dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(y)
    return list(outs[:-1]), outs[-1][:, 0]  # ([v_1, …], raw acc (B, m))


def _make_batched_apply_kernel(norms: Sequence[str]):
    """The apply epilogue with the batch axis as grid dimension 0."""
    L = len(norms) + 1
    has_vfin = norms[-1] == "2"

    def kernel(*refs):
        y_ref, v_refs = refs[0], refs[1:L - 1]
        vfin_ref = refs[L - 1] if has_vfin else None
        u_ref, out_ref = refs[-2], refs[-1]
        stages = [y_ref[...][0]] + [v[...][0] for v in v_refs]
        vfin = vfin_ref[...][0] if has_vfin else None
        out_ref[...] = _apply_tile(norms, stages, vfin, u_ref[...][0])[None]

    return kernel


def _apply_call_batched(y: jax.Array, aggs, vfin: jax.Array, u: jax.Array,
                        tp: TilePlan, norms: Sequence[str], interpret: bool):
    batch = y.shape[0]
    grid = (batch, pl.cdiv(tp.m, tp.block_m), pl.cdiv(tp.n, tp.block_n))
    agg_specs, _ = _agg_specs_shapes_batched(tp, y.dtype, batch)
    row = lambda v: v.reshape(batch, 1, tp.m).astype(y.dtype)  # noqa: E731
    rows = ([row(vfin)] if norms[-1] == "2" else []) + [row(u)]
    return pl.pallas_call(
        _make_batched_apply_kernel(norms),
        grid=grid,
        in_specs=[_y_spec_batched(tp)] + agg_specs
                 + [_row_spec_batched(tp)] * len(rows),
        out_specs=_y_spec_batched(tp),
        out_shape=jax.ShapeDtypeStruct((batch,) + tp.canon_shape, y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, *aggs, *rows)


def _solve_outer_batched(v: jax.Array, norm: str, radii: jax.Array,
                         method: str, interpret: bool) -> jax.Array:
    """Per-item outer solves on the (B, m) finalized aggregates.

    The ℓ1 case runs the batched single-block θ kernel (batch in the grid,
    per-item radii in SMEM); ℓ2/ℓ∞ are a batched rescale/clip.
    """
    radii = jnp.asarray(radii, v.dtype)
    if norm == "1":
        from ..l1ball import L1_KERNEL_MAX, project_l1_pallas_batched

        resolved = ball.resolve_method(method)
        if v.shape[-1] <= L1_KERNEL_MAX and resolved in ("bisect", "filter"):
            return project_l1_pallas_batched(v, radii, method=resolved,
                                             interpret=interpret)
        return jax.vmap(
            lambda vv, rr: ball.project_l1(vv, rr, method=method))(v, radii)
    if norm == "2":
        return jax.vmap(ball.project_l2)(v, radii)
    return jnp.minimum(v, radii[:, None])  # ℓ∞ on v ≥ 0


def generate_batched(sched: Schedule, dtype, *, method: str = "bisect",
                     interpret: bool = False,
                     tile_plan: TilePlan | None = None) -> Callable:
    """Compile ``sched`` into a fused batched ``(ys, radii) -> xs`` callable.

    ``ys`` stacks B instances of ``sched.shape`` along a leading axis with a
    per-item ``radii`` vector of length B — the serving-bucket execution
    shape. Unlike :func:`generate` (whose plan backend is vmapped by the
    planner for ``radius_kind="batch"`` keys), the batch axis here IS a Pallas
    grid dimension, so the whole bucket is one reduce dispatch + one θ-solve
    dispatch + one apply dispatch. B is read from ``ys`` at trace time (each
    new bucket size traces once — serving pads to pow-2 buckets).
    """
    if sched.batch_dims:
        raise ValueError(
            "generate_batched takes a batch-free schedule; the stacked "
            "serving axis is the callable's leading axis, not a schedule "
            "batch dim")
    tp = _resolve_tile_plan(sched, dtype, tile_plan)
    norms = [q for q, _ in sched.levels]

    def raw(ys, radii):
        """Forward pipeline; also returns the VJP residual aggregates."""
        batch = ys.shape[0]
        yc = ys.reshape((batch,) + tp.canon_shape)
        if len(norms) == 1:
            with obs_profile.scope(f"codegen_solve_{norms[0]}"):
                out = _solve_outer_batched(yc, norms[0], radii, method,
                                           interpret)
            return out.reshape(ys.shape), ()
        with obs_profile.scope("codegen_reduce"):
            aggs, acc = _reduce_call_batched(yc, tp, norms[:-1], interpret)
            vfin = MONOIDS[norms[-2]].finalize(acc)
        with obs_profile.scope(f"codegen_solve_{norms[-1]}"):
            u = _solve_outer_batched(vfin, norms[-1], radii, method,
                                     interpret)
        with obs_profile.scope("codegen_apply"):
            x = _apply_call_batched(yc, aggs, vfin, u, tp, norms[:-1],
                                    interpret)
        return x.reshape(ys.shape), (tuple(aggs), vfin, u)

    @jax.custom_vjp
    def fused(ys, radii):
        return raw(ys, radii)[0]

    def fwd(ys, radii):
        x, internals = raw(ys, radii)
        return x, (ys, x, internals, radii)

    def bwd(res, g):
        # per-item generated residual VJP, vmapped over the stacked batch —
        # same no-re-execution property as the single-item path
        ys, x, internals, radii = res
        batch = ys.shape[0]
        yc = ys.reshape((batch,) + tp.canon_shape)
        xc = x.reshape((batch,) + tp.canon_shape)
        gc = g.reshape((batch,) + tp.canon_shape)
        if len(norms) == 1:
            def item(y1, x1, g1, r1):
                return bwd_mod.schedule_vjp(norms, [y1], x1, x1, r1, g1)
            dy, dr = jax.vmap(item)(yc, xc, gc, radii)
        else:
            aggs, vfin, u = internals

            def item(y1, aggs1, vfin1, u1, x1, g1, r1):
                return bwd_mod.schedule_vjp(norms, [y1, *aggs1, vfin1],
                                            u1, x1, r1, g1)
            dy, dr = jax.vmap(item)(yc, aggs, vfin, u, xc, gc, radii)
        return dy.reshape(ys.shape), dr.astype(radii.dtype)

    fused.defvjp(fwd, bwd)

    @functools.wraps(fused)
    def entry(ys, radii):
        ys = jnp.asarray(ys)
        radii = jnp.asarray(radii, ys.dtype)
        if ys.ndim != len(sched.shape) + 1:
            raise ValueError(
                f"batched kernel built for item shape {sched.shape} expects "
                f"rank {len(sched.shape) + 1} stacked input, got {ys.shape}")
        if radii.ndim != 1 or radii.shape[0] != ys.shape[0]:
            raise ValueError(
                f"radii must be one scalar per stacked item: got "
                f"{radii.shape} for batch {ys.shape[0]}")
        return fused(ys, radii)

    return entry
