"""repro.kernels.codegen — compile any schedule IR to fused Pallas kernels.

The subsystem has three layers (DESIGN.md §4, "IR → Pallas lowering"):

* ``tiling``   — the grid/block planner: collapses a compiled ``Schedule`` to
  its canonical ``(g_1, …, g_{L-1}, m)`` view and picks VMEM-resident block
  sizes (or rejects the design);
* ``lowering`` — emits the fused kernels: one streaming reduce pass producing
  every forward aggregate, the tiny outer θ-solve, one fused apply epilogue;
* this module — the cached entry points the planner backend
  (``kernels/plan_backends.py``) and the ``ops`` dispatchers build on.

Generated kernels are pinned against the hand-written golden kernels
(``bilevel_l1inf.py`` / ``trilevel_l1infinf.py``) by ``tests/test_codegen.py``
and benchmarked against them by ``benchmarks/run.py --only codegen``.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import compile_schedule, canonical_levels

from . import lowering, tiling  # noqa: F401
from .lowering import generate, generate_batched  # noqa: F401
from .tiling import (BatchedTilePlan, TilePlan,  # noqa: F401
                     candidate_tile_plans, plan_batched_tiles, plan_tiles)


# measured block-size winners, keyed on (canonical shape via schedule key,
# dtype, device platform, interpret) — one shoot-out per workload, ever
_TUNED_TILES: Dict[Tuple, TilePlan] = {}
_TUNE_REPS = 3          # interleaved min-of-rounds (the planner's protocol)


def clear_tile_cache() -> None:
    """Drop every cached block-size verdict (benches/tests)."""
    _TUNED_TILES.clear()


def autotune_tiles(shape, levels, dtype, *, method: str = "bisect",
                   interpret: bool = False,
                   measure: Optional[bool] = None) -> Optional[TilePlan]:
    """Measured block-size search: shoot out ``candidate_tile_plans`` the way
    ``method="auto"`` shoots out planner backends, and cache the winner per
    (canonical shape, dtype, device, interpret).

    Each candidate's FULL fused pipeline (reduce → θ-solve → apply) is jitted
    and timed interleaved min-of-rounds on synthetic data of the exact
    workload. ``measure=None`` defaults to measuring only on real hardware:
    in interpret mode block sizes change no machine behaviour (tests would
    pay the shoot-out for a meaningless verdict), so the heuristic default is
    returned — benches that want the interpret-mode search anyway pass
    ``measure=True``. Returns ``None`` when the design cannot be generated.
    """
    shape = tuple(int(s) for s in shape)
    levels = canonical_levels(levels)
    dtype = np.dtype(dtype)
    device = jax.devices()[0].platform
    key = (shape, levels, dtype.name, device, bool(interpret))
    if key in _TUNED_TILES:
        return _TUNED_TILES[key]
    sched = compile_schedule(shape, levels)
    base = compile_schedule(shape[sched.batch_dims:], levels) \
        if sched.batch_dims else sched
    cands = candidate_tile_plans(base, dtype)
    if not cands:
        return None
    if measure is None:
        measure = not interpret
    if len(cands) == 1 or not measure:
        _TUNED_TILES[key] = cands[0]
        return cands[0]
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.uniform(0.0, 1.0, shape), dtype)
    r = jnp.asarray(1.0, dtype)
    fns = [jax.jit(lowering.generate(sched, dtype, method=method,
                                     interpret=interpret, tile_plan=tp))
           for tp in cands]
    for fn in fns:
        for _ in range(2):
            jax.block_until_ready(fn(y, r))  # compile + warm
    best = [float("inf")] * len(fns)
    for _ in range(_TUNE_REPS):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(y, r))
            best[i] = min(best[i], time.perf_counter() - t0)
    winner = cands[int(np.argmin(best))]
    _TUNED_TILES[key] = winner
    return winner


def supported(shape, levels, dtype) -> bool:
    """True when the tiler accepts (shape, levels, dtype) — the availability
    gate of the ``codegen`` planner backend (device checks live there)."""
    try:
        sched = compile_schedule(shape, levels)
    except ValueError:
        return False
    return plan_tiles(sched, dtype) is not None


@functools.lru_cache(maxsize=None)
def _cached_build(shape, levels, dtype_name: str, method: str,
                  interpret: bool, jit: bool,
                  tile_plan: Optional[TilePlan]) -> Callable:
    sched = compile_schedule(shape, levels)
    fn = lowering.generate(sched, np.dtype(dtype_name), method=method,
                           interpret=interpret, tile_plan=tile_plan)
    return jax.jit(fn) if jit else fn


def build(shape, levels, dtype, *, method: str = "bisect",
          interpret: bool = False, jit: bool = False,
          tile_plan: Optional[TilePlan] = None) -> Callable:
    """Generate (or fetch from cache) the fused ``(y, radius) -> x`` kernel
    for one workload. ``method`` selects the outer θ-solve backend;
    ``tile_plan`` overrides the heuristic block sizes (``TilePlan`` is a
    hashable NamedTuple, so it joins the cache key)."""
    return _cached_build(tuple(int(s) for s in shape),
                         canonical_levels(levels), np.dtype(dtype).name,
                         method, bool(interpret), bool(jit), tile_plan)


def build_tuned(shape, levels, dtype, *, method: str = "bisect",
                interpret: bool = False, jit: bool = False,
                measure: Optional[bool] = None) -> Callable:
    """Like :func:`build`, but with measured block sizes: runs (or fetches)
    the :func:`autotune_tiles` shoot-out for the workload and builds with the
    winning :class:`TilePlan`. The planner backend's build path."""
    tp = autotune_tiles(shape, levels, dtype, method=method,
                        interpret=interpret, measure=measure)
    return build(shape, levels, dtype, method=method, interpret=interpret,
                 jit=jit, tile_plan=tp)


@functools.lru_cache(maxsize=None)
def _cached_build_batched(shape, levels, dtype_name: str, method: str,
                          interpret: bool, jit: bool) -> Callable:
    sched = compile_schedule(shape, levels)
    fn = lowering.generate_batched(sched, np.dtype(dtype_name), method=method,
                                   interpret=interpret)
    return jax.jit(fn) if jit else fn


def build_batched(shape, levels, dtype, *, method: str = "bisect",
                  interpret: bool = False, jit: bool = False) -> Callable:
    """Generate (or fetch from cache) the batched-grid ``(ys, radii) -> xs``
    kernel for a serving bucket of ``shape``-shaped items (the stacked batch
    axis joins the Pallas grid; see :func:`lowering.generate_batched`)."""
    return _cached_build_batched(tuple(int(s) for s in shape),
                                 canonical_levels(levels),
                                 np.dtype(dtype).name, method,
                                 bool(interpret), bool(jit))


def codegen_project(y: jax.Array, levels: Sequence, radius, *,
                    method: str = "bisect", interpret: bool = False) -> jax.Array:
    """Project ``y`` with a generated fused kernel (eager entry point).

    The generated executable is cached per (shape, dtype, levels, method,
    interpret) and jitted, so repeat calls pay only dispatch.
    """
    y = jnp.asarray(y)
    fn = build(jnp.shape(y), levels, y.dtype, method=method,
               interpret=interpret, jit=True)
    return fn(y, radius)
