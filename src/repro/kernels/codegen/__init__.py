"""repro.kernels.codegen — compile any schedule IR to fused Pallas kernels.

The subsystem has three layers (DESIGN.md §4, "IR → Pallas lowering"):

* ``tiling``   — the grid/block planner: collapses a compiled ``Schedule`` to
  its canonical ``(g_1, …, g_{L-1}, m)`` view and picks VMEM-resident block
  sizes (or rejects the design);
* ``lowering`` — emits the fused kernels: one streaming reduce pass producing
  every forward aggregate, the tiny outer θ-solve, one fused apply epilogue;
* this module — the cached entry points the planner backend
  (``kernels/plan_backends.py``) and the ``ops`` dispatchers build on.

Generated kernels are pinned against the hand-written golden kernels
(``bilevel_l1inf.py`` / ``trilevel_l1infinf.py``) by ``tests/test_codegen.py``
and benchmarked against them by ``benchmarks/run.py --only codegen``.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import compile_schedule, canonical_levels

from . import lowering, tiling  # noqa: F401
from .lowering import generate, generate_batched  # noqa: F401
from .tiling import (BatchedTilePlan, TilePlan,  # noqa: F401
                     plan_batched_tiles, plan_tiles)


def supported(shape, levels, dtype) -> bool:
    """True when the tiler accepts (shape, levels, dtype) — the availability
    gate of the ``codegen`` planner backend (device checks live there)."""
    try:
        sched = compile_schedule(shape, levels)
    except ValueError:
        return False
    return plan_tiles(sched, dtype) is not None


@functools.lru_cache(maxsize=None)
def _cached_build(shape, levels, dtype_name: str, method: str,
                  interpret: bool, jit: bool) -> Callable:
    sched = compile_schedule(shape, levels)
    fn = lowering.generate(sched, np.dtype(dtype_name), method=method,
                           interpret=interpret)
    return jax.jit(fn) if jit else fn


def build(shape, levels, dtype, *, method: str = "bisect",
          interpret: bool = False, jit: bool = False) -> Callable:
    """Generate (or fetch from cache) the fused ``(y, radius) -> x`` kernel
    for one workload. ``method`` selects the outer θ-solve backend."""
    return _cached_build(tuple(int(s) for s in shape),
                         canonical_levels(levels), np.dtype(dtype).name,
                         method, bool(interpret), bool(jit))


@functools.lru_cache(maxsize=None)
def _cached_build_batched(shape, levels, dtype_name: str, method: str,
                          interpret: bool, jit: bool) -> Callable:
    sched = compile_schedule(shape, levels)
    fn = lowering.generate_batched(sched, np.dtype(dtype_name), method=method,
                                   interpret=interpret)
    return jax.jit(fn) if jit else fn


def build_batched(shape, levels, dtype, *, method: str = "bisect",
                  interpret: bool = False, jit: bool = False) -> Callable:
    """Generate (or fetch from cache) the batched-grid ``(ys, radii) -> xs``
    kernel for a serving bucket of ``shape``-shaped items (the stacked batch
    axis joins the Pallas grid; see :func:`lowering.generate_batched`)."""
    return _cached_build_batched(tuple(int(s) for s in shape),
                                 canonical_levels(levels),
                                 np.dtype(dtype).name, method,
                                 bool(interpret), bool(jit))


def codegen_project(y: jax.Array, levels: Sequence, radius, *,
                    method: str = "bisect", interpret: bool = False) -> jax.Array:
    """Project ``y`` with a generated fused kernel (eager entry point).

    The generated executable is cached per (shape, dtype, levels, method,
    interpret) and jitted, so repeat calls pay only dispatch.
    """
    y = jnp.asarray(y)
    fn = build(jnp.shape(y), levels, y.dtype, method=method,
               interpret=interpret, jit=True)
    return fn(y, radius)
