"""repro.kernels — Pallas TPU kernels for the projection + attention hot spots.

``codegen/`` compiles any schedule IR to fused kernels; the hand-written
``bilevel_l1inf.py`` / ``trilevel_l1infinf.py`` kernels are the golden
references its equality tests pin against. Every kernel has a pure-jnp oracle
in ref.py; tests sweep shapes/dtypes in interpret mode against it. ops.py
holds the planner-routed dispatchers.
"""

from .bilevel_l1inf import bilevel_l1inf_pallas, clip_pallas, colmax_pallas  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .l1ball import KERNEL_METHODS, project_l1_pallas  # noqa: F401
from .trilevel_l1infinf import trilevel_l1infinf_pallas  # noqa: F401
from . import codegen, ops, plan_backends, ref  # noqa: F401
