"""jit'd dispatch wrappers for the Pallas kernels.

``use_pallas()`` is True only on real TPU devices; the CPU container (tests,
dry-run) uses interpret mode when asked explicitly and the jnp oracles
otherwise, so lowering for the 512-device dry-run never requires Mosaic.
"""

from __future__ import annotations

import functools

import jax

from . import ref
from .bilevel_l1inf import bilevel_l1inf_pallas
from .flash_attention import flash_attention
from .trilevel_l1infinf import trilevel_l1infinf_pallas


def use_pallas() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("method", "interpret", "force"))
def bilevel_l1inf(y: jax.Array, radius, *, method: str = "bisect",
                  interpret: bool = False, force: bool = False) -> jax.Array:
    """Bi-level ℓ1,∞ projection — Pallas on TPU, jnp oracle elsewhere.

    ``method`` selects the outer ℓ1 solve ("bisect" | "filter" have VMEM
    kernels; anything else — e.g. "sort" — runs the jnp backend for the outer
    step). ``force=True`` routes through the kernels regardless of platform
    (with ``interpret=True`` on CPU: the per-kernel correctness tests).
    """
    if force or use_pallas():
        return bilevel_l1inf_pallas(y, radius, method=method,
                                    interpret=interpret)
    return ref.bilevel_l1inf_ref(y, radius, method=method)


@functools.partial(jax.jit, static_argnames=("method", "interpret", "force"))
def trilevel_l1infinf(y: jax.Array, radius, *, method: str = "bisect",
                      interpret: bool = False, force: bool = False) -> jax.Array:
    """Tri-level ℓ1,∞,∞ projection — fused Pallas on TPU, jnp oracle elsewhere.

    Same contract as ``bilevel_l1inf``: ``method`` picks the outer θ-solve,
    ``force=True`` routes through the kernels regardless of platform.
    """
    if force or use_pallas():
        return trilevel_l1infinf_pallas(y, radius, method=method,
                                        interpret=interpret)
    return ref.trilevel_l1infinf_ref(y, radius, method=method)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret", "force"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              interpret: bool = False, force: bool = False):
    """Flash attention fwd — Pallas on TPU, chunked-jnp oracle elsewhere."""
    if force or use_pallas():
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
