"""Dispatch wrappers for the Pallas kernels.

Dispatch is planner-routed, not platform-hand-rolled: the projection entry
points run the **generated fused kernels** (``kernels/codegen``) when the
workload's device is a TPU (or when forced), and otherwise execute through a
cached ``core.plan`` projection plan — the jitted jnp schedule path.

``use_pallas(y)`` gates on the committed device of the *input* array when it
has one (a CPU-committed array on a TPU host keeps the jnp path and vice
versa), falling back to the default backend device. Setting
``REPRO_FORCE_INTERPRET=1`` flips every kernel path into Pallas interpret
mode, so CPU debugging of kernels does not require threading
``interpret=True`` through each call site by hand.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention

_BILEVEL_LEVELS = (("inf", 1), ("1", 1))
_TRILEVEL_LEVELS = (("inf", 1), ("inf", 1), ("1", 1))


def force_interpret() -> bool:
    """True when ``REPRO_FORCE_INTERPRET`` asks for Pallas interpret mode."""
    return os.environ.get("REPRO_FORCE_INTERPRET", "").strip().lower() in (
        "1", "true", "yes", "on")


def use_pallas(y=None) -> bool:
    """True when the workload should run the Pallas kernels.

    Gates on the committed device of ``y`` when it is a concrete array (the
    workload's actual placement), the default backend device otherwise —
    never on the bare ``jax.devices()[0]`` of whatever backend loaded first.
    """
    platform = None
    if y is not None and not isinstance(y, jax.core.Tracer):
        devices = getattr(y, "devices", None)
        if callable(devices):
            try:
                platform = next(iter(y.devices())).platform
            except Exception:
                platform = None
    if platform is None:
        platform = jax.devices()[0].platform
    return platform == "tpu"


def _projection(y, levels, radius, method: str, interpret: bool, force: bool):
    interpret = bool(interpret) or force_interpret()
    if force or use_pallas(y):
        from .codegen import codegen_project

        return codegen_project(y, list(levels), radius, method=method,
                               interpret=interpret)
    from repro.core import plan as planmod

    p = planmod.make_plan(jnp.shape(y), jnp.result_type(y), list(levels),
                          method=method)
    return p(y, radius)


def bilevel_l1inf(y: jax.Array, radius, *, method: str = "bisect",
                  interpret: bool = False, force: bool = False) -> jax.Array:
    """Bi-level ℓ1,∞ projection — generated fused kernel on TPU, planner-cached
    jnp schedule elsewhere.

    ``method`` selects the outer ℓ1 solve ("bisect" | "filter" have VMEM
    kernels; anything else — e.g. "sort" — runs the outer step on the jnp
    backend). ``force=True`` routes through the kernels regardless of platform
    (with ``interpret=True`` — or ``REPRO_FORCE_INTERPRET=1`` — on CPU: the
    per-kernel correctness tests).
    """
    return _projection(y, _BILEVEL_LEVELS, radius, method, interpret, force)


def trilevel_l1infinf(y: jax.Array, radius, *, method: str = "bisect",
                      interpret: bool = False, force: bool = False) -> jax.Array:
    """Tri-level ℓ1,∞,∞ projection — same contract as ``bilevel_l1inf``."""
    if jnp.ndim(y) != 3:
        raise ValueError("trilevel_l1infinf expects an order-3 tensor")
    return _projection(y, _TRILEVEL_LEVELS, radius, method, interpret, force)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret", "use_kernel"))
def _attention(q, k, v, *, causal, window, interpret, use_kernel):
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              interpret: bool = False, force: bool = False):
    """Flash attention fwd — Pallas on TPU, chunked-jnp oracle elsewhere."""
    return _attention(q, k, v, causal=causal, window=window,
                      interpret=bool(interpret) or force_interpret(),
                      use_kernel=bool(force or use_pallas(q)))
