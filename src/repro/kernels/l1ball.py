"""Pallas TPU kernel: ℓ1-ball projection of a vector by bisection.

The outer step of the bi-level projection. Serial-optimal algorithms
(Condat/Michelot) do not map to the VPU; bisection does — each iteration is an
elementwise soft-threshold + a tree reduction, all inside VMEM (DESIGN.md §3).

Single-block kernel: the whole (padded) vector lives in VMEM. That covers the
aggregate vectors of every assigned architecture (d_ff ≤ 25600, experts ≤ 384,
vocab ≤ 163840 → ≤ 640 KB f32). ``ops.py`` falls back to the jnp path for
anything larger.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ITERS = 64
_LANE = 128


def _l1ball_kernel(v_ref, radius_ref, out_ref, *, n_total: int, iters: int):
    v = v_ref[...]  # (1, n_pad)
    radius = radius_ref[0]
    ids = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    valid = ids < n_total
    a = jnp.where(valid, jnp.abs(v), 0.0)

    inside = jnp.sum(a) <= radius

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - mid, 0.0))
        too_small = phi > radius
        lo = jnp.where(too_small, mid, lo)
        hi = jnp.where(too_small, hi, mid)
        return lo, hi

    lo0 = jnp.zeros((), v.dtype)
    hi0 = jnp.max(a)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    theta = jnp.where(inside, jnp.zeros((), v.dtype), 0.5 * (lo + hi))
    out_ref[...] = jnp.sign(v) * jnp.maximum(a - theta, 0.0)


def project_l1_pallas(v: jax.Array, radius, *, iters: int = _ITERS,
                      interpret: bool = False) -> jax.Array:
    """Project a 1-D vector onto the ℓ1 ball of ``radius`` (bisection, VMEM)."""
    (n,) = v.shape
    n_pad = max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE)
    v2 = jnp.zeros((1, n_pad), v.dtype).at[0, :n].set(v)
    r = jnp.asarray(radius, v.dtype).reshape(1)
    out = pl.pallas_call(
        functools.partial(_l1ball_kernel, n_total=n, iters=iters),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), v.dtype),
        interpret=interpret,
    )(v2, r)
    return out[0, :n]
