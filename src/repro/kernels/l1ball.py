"""Pallas TPU kernels: ℓ1-ball projection of a vector.

The outer step of the bi-level projection. Two in-VMEM algorithms:

* ``bisect`` — k fixed iterations of soft-threshold + tree reduction. Serial
  depth k·log n, fully VPU-shaped (DESIGN.md §4). Accuracy ~2^-k.
* ``filter`` — Michelot/Condat filtering: a ``lax.while_loop`` fixed point on
  the threshold θ over a shrinking active set (masking, no sorting). Converges
  exactly in a handful of sweeps on typical data — O(n) expected work versus
  the bisect kernel's fixed 64 sweeps.

Serial-optimal heap/partition variants do not map to the VPU; both kernels use
only elementwise ops + reductions. Single-block kernels: the whole (padded)
vector lives in VMEM. That covers the aggregate vectors of every assigned
architecture (d_ff ≤ 25600, experts ≤ 384, vocab ≤ 163840 → ≤ 640 KB f32).
``ops.py`` falls back to the jnp path for anything larger.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

_ITERS = 64
_LANE = 128


def _masked_abs(v_ref, n_total: int):
    v = v_ref[...]  # (1, n_pad)
    ids = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    a = jnp.where(ids < n_total, jnp.abs(v), 0.0)
    return v, a


def _l1ball_bisect_kernel(v_ref, radius_ref, out_ref, *, n_total: int, iters: int):
    v, a = _masked_abs(v_ref, n_total)
    radius = radius_ref[0]
    inside = jnp.sum(a) <= radius

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - mid, 0.0))
        too_small = phi > radius
        lo = jnp.where(too_small, mid, lo)
        hi = jnp.where(too_small, hi, mid)
        return lo, hi

    lo0 = jnp.zeros((), v.dtype)
    hi0 = jnp.max(a)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    theta = jnp.where(inside, jnp.zeros((), v.dtype), 0.5 * (lo + hi))
    out_ref[...] = jnp.sign(v) * jnp.maximum(a - theta, 0.0)


def _l1ball_filter_kernel(v_ref, radius_ref, out_ref, *, n_total: int, iters: int):
    """Michelot filtering in VMEM: θ ← (Σ_{aᵢ>θ} aᵢ - r)/#{aᵢ>θ} to fixpoint.

    Outside the ball θ is strictly positive and non-decreasing, so the zero
    padding (and true zeros) can never enter the active set — the mask IS the
    shrinking active set, no compaction needed. ``iters`` caps the sweep count
    (termination is guaranteed in ≤ n sweeps; typical data needs < 10).
    """
    v, a = _masked_abs(v_ref, n_total)
    radius = radius_ref[0]
    s0 = jnp.sum(a)
    inside = s0 <= radius
    theta0 = (s0 - radius) / n_total

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < iters)

    def body(state):
        theta, count, _, it = state
        active = a > theta
        new_count = jnp.sum(active.astype(jnp.int32))
        ssum = jnp.sum(jnp.where(active, a, 0.0))
        new_theta = jnp.where(
            new_count > 0,
            (ssum - radius) / jnp.maximum(new_count, 1).astype(a.dtype),
            theta,
        )
        changed = jnp.logical_and(new_count != count, new_count > 0)
        return new_theta, new_count, changed, it + 1

    theta, _, _, _ = jax.lax.while_loop(
        cond, body, (theta0, jnp.int32(n_total), jnp.bool_(True), jnp.int32(0)))
    theta = jnp.where(inside, jnp.zeros((), v.dtype), jnp.maximum(theta, 0.0))
    out_ref[...] = jnp.sign(v) * jnp.maximum(a - theta, 0.0)


# threshold-kernel dispatch — keyed by the core.ball backend names ("sort" has
# no VPU mapping; outer_l1_solve routes it to the jnp oracle instead)
_THRESHOLD_KERNELS = {
    "bisect": _l1ball_bisect_kernel,
    "filter": _l1ball_filter_kernel,
}

KERNEL_METHODS = tuple(sorted(_THRESHOLD_KERNELS))

# vectors larger than this stay on the jnp path (single-block VMEM kernel limit)
L1_KERNEL_MAX = 512 * 1024


def outer_l1_solve(v: jax.Array, radius, *, method: str = "bisect",
                   interpret: bool = False) -> jax.Array:
    """The fused kernels' outer θ-solve: VMEM kernel when ``method`` has one
    and ``v`` fits a single block, jnp backend otherwise."""
    if v.shape[0] <= L1_KERNEL_MAX and method in KERNEL_METHODS:
        return project_l1_pallas(v, radius, method=method, interpret=interpret)
    from .ref import project_l1_ref
    return project_l1_ref(v, radius, method=method)


def project_l1_pallas(v: jax.Array, radius, *, method: str = "bisect",
                      iters: int | None = None, interpret: bool = False) -> jax.Array:
    """Project a 1-D vector onto the ℓ1 ball of ``radius`` in VMEM.

    ``method`` ∈ {"bisect", "filter"} selects the threshold kernel.
    """
    if method not in _THRESHOLD_KERNELS:
        raise ValueError(
            f"no pallas threshold kernel for method {method!r}; "
            f"available: {sorted(_THRESHOLD_KERNELS)}"
        )
    (n,) = v.shape
    if iters is None:
        # filter terminates in <= n sweeps; bisect needs its fixed budget
        iters = n + 2 if method == "filter" else _ITERS
    n_pad = max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE)
    v2 = jnp.zeros((1, n_pad), v.dtype).at[0, :n].set(v)
    r = jnp.asarray(radius, v.dtype).reshape(1)
    out = pl.pallas_call(
        functools.partial(_THRESHOLD_KERNELS[method], n_total=n, iters=iters),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), v.dtype),
        interpret=interpret,
    )(v2, r)
    return out[0, :n]


def project_l1_pallas_batched(v: jax.Array, radii: jax.Array, *,
                              method: str = "bisect", iters: int | None = None,
                              interpret: bool = False) -> jax.Array:
    """Project every row of ``v`` (B, n) onto its own ℓ1 ball, batched grid.

    The serving-bucket form of :func:`project_l1_pallas`: the batch axis is a
    PARALLEL Pallas grid dimension (one program per request) and the per-item
    radii ride in SMEM, block-sliced by the batch grid index — the same kernel
    bodies as the single-item version, no vmap lifting. Each program keeps its
    whole (padded) row in VMEM, so the single-block size limit
    (``L1_KERNEL_MAX``) applies per item, not to the batch.
    """
    if method not in _THRESHOLD_KERNELS:
        raise ValueError(
            f"no pallas threshold kernel for method {method!r}; "
            f"available: {sorted(_THRESHOLD_KERNELS)}"
        )
    b, n = v.shape
    if iters is None:
        iters = n + 2 if method == "filter" else _ITERS
    n_pad = max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE)
    v2 = jnp.zeros((b, n_pad), v.dtype).at[:, :n].set(v)
    r = jnp.asarray(radii, v.dtype).reshape(b)
    out = pl.pallas_call(
        functools.partial(_THRESHOLD_KERNELS[method], n_total=n, iters=iters),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), v.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(v2, r)
    return out[:, :n]
