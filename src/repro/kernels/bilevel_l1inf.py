"""Pallas TPU kernels for the bi-level ℓ1,∞ projection (paper Algorithm 2).

GOLDEN REFERENCE: since the kernel code generator landed
(``kernels/codegen``, DESIGN.md §4 "IR → Pallas lowering"), this hand-written
kernel is no longer a planner backend — it pins the generated bi-level kernel
in ``tests/test_codegen.py`` and baselines it in
``benchmarks/run.py --only codegen``.

The projection is bandwidth-bound (O(1) FLOP/byte), so the kernels are tiled
HBM→VMEM streaming passes (DESIGN.md §4):

  pass 1  colmax:  v[j]   = max_i |Y[i, j]|        (grid-reduced over row blocks)
  (tiny)  outer :  u      = P¹_η(v)                (jnp or the l1ball kernel)
  pass 2  clip  :  X[i,j] = clip(Y[i,j], ±u[j])    (elementwise, broadcast u)

Y is read exactly twice — the information-theoretic minimum for the split.
Blocks are (block_n, block_m) with the lane dimension a multiple of 128 and the
sublane dimension a multiple of 8 (f32) for MXU/VPU alignment; ragged edges are
handled by index-map clamping + masking in the kernel.

On TPU the grid's *last* axis is the sequential one: we place row-blocks last
so the colmax accumulation into ``out_ref`` is legal (PARALLEL over column
blocks, ARBITRARY over row blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams

DEFAULT_BLOCK_N = 256   # rows per tile (sublane axis)
DEFAULT_BLOCK_M = 512   # cols per tile (lane axis)


def _colmax_kernel(y_ref, out_ref, *, n_total: int, block_n: int):
    """out[0, j] = max over row-blocks of max_i |y[i, j]| (accumulated)."""
    i = pl.program_id(1)  # sequential row-block index (last grid axis)
    rows_done = i * block_n
    # mask rows past the true edge with 0 (|.| >= 0 so 0 is the identity here)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, y_ref.shape, 0) + rows_done
    valid = row_ids < n_total
    block = jnp.where(valid, jnp.abs(y_ref[...]), 0.0)
    part = jnp.max(block, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], part)


def _clip_kernel(y_ref, u_ref, out_ref):
    """out = clip(y, -u, u) with u broadcast down the rows of the tile."""
    u = u_ref[...]  # (1, block_m)
    out_ref[...] = jnp.clip(y_ref[...], -u, u)


def bilevel_l1inf_pallas(y: jax.Array, radius, *, method: str = "bisect",
                         block_n: int = DEFAULT_BLOCK_N,
                         block_m: int = DEFAULT_BLOCK_M,
                         interpret: bool = False) -> jax.Array:
    """Fused bi-level ℓ1,∞ projection: colmax → outer P¹ → clip, all Pallas.

    ``method`` selects the outer-step threshold kernel ("bisect" or the
    linear-time "filter"); anything else — or a vector past the single-block
    VMEM limit — runs the outer solve on the jnp backend instead.
    """
    from .l1ball import outer_l1_solve

    v = colmax_pallas(y, block_n=block_n, block_m=block_m, interpret=interpret)
    u = outer_l1_solve(v, radius, method=method, interpret=interpret)
    return clip_pallas(y, u, block_n=block_n, block_m=block_m, interpret=interpret)


def colmax_pallas(y: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                  block_m: int = DEFAULT_BLOCK_M, interpret: bool = False) -> jax.Array:
    """Per-column max|·| of a 2-D array via a tiled grid reduction."""
    n, m = y.shape
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(128, m))
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    out = pl.pallas_call(
        functools.partial(_colmax_kernel, n_total=n, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_m), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, block_m), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, m), y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(y)
    return out[0]


def clip_pallas(y: jax.Array, u: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                block_m: int = DEFAULT_BLOCK_M, interpret: bool = False) -> jax.Array:
    """X = clip(Y, ±u) with u a per-column radius vector."""
    n, m = y.shape
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(128, m))
    grid = (pl.cdiv(n, block_n), pl.cdiv(m, block_m))
    u2 = u.reshape(1, m).astype(y.dtype)
    return pl.pallas_call(
        _clip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, u2)
