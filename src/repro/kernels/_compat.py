"""Version-compat shims for the Pallas TPU API."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
