"""Generated fused kernels as a projection-planner backend (DESIGN.md §2/§4).

Importing this module registers the ``codegen`` and ``codegen_batch``
backends with ``repro.core.plan`` (the planner imports it lazily on first
``make_plan``, so ``core`` never imports ``kernels`` at module load): the
kernel code generator (``kernels/codegen``) lowers ANY unsharded norm design
the tiler accepts to a fused reduce → θ-solve → apply kernel pipeline —
eligible on TPU, or anywhere under ``interpret=True`` (correctness tests
only; interpret mode is orders of magnitude slower than the jnp path, so
``method="auto"`` will never pick it off-TPU, by measurement).
``codegen_batch`` is the serving-bucket variant: batch-native (the stacked
batch axis joins the Pallas grid, per-item radii in SMEM), competing only on
``radius_kind="batch"`` plan keys.

The hand-written fused kernels (``bilevel_l1inf.py``/``trilevel_l1infinf.py``)
are no longer registered as backends: they are the *golden references* the
codegen equality tests pin against (``tests/test_codegen.py``) and the
baseline of ``benchmarks/run.py --only codegen``.
"""

from __future__ import annotations

from repro.core import plan as planmod

from . import codegen

# the outer θ-solve of generated kernels: "bisect" has a VMEM kernel and no
# data-dependent sweep count (stable latency for a served plan)
_OUTER_METHOD = "bisect"


def _codegen_available(key: planmod.PlanKey) -> bool:
    # single-device workloads only: a mesh-sharded key routes to the sharded
    # schedule executor, not to a fused single-chip kernel.  Training keys
    # (key.grad) are eligible too: the generated kernels carry a generated
    # residual-VJP backward (kernels/codegen/backward.py) — no sort-oracle
    # recompute — so for grad keys the autotuner times them under
    # value_and_grad like any other candidate.
    if key.sharding is not None or not (key.device == "tpu" or key.interpret):
        return False
    return codegen.supported(key.shape, key.levels, key.dtype)


def _build_codegen(key: planmod.PlanKey):
    return codegen.build(key.shape, key.levels, key.dtype,
                         method=_OUTER_METHOD, interpret=key.interpret)


planmod.register_plan_backend(planmod.PlanBackend(
    name="codegen",
    available=_codegen_available,
    build=_build_codegen,
    description="generated fused Pallas kernels: one streaming reduce pass "
                "-> VMEM theta-solve -> fused apply epilogue (kernels/codegen)",
))


def _build_codegen_batch(key: planmod.PlanKey):
    return codegen.build_batched(key.shape, key.levels, key.dtype,
                                 method=_OUTER_METHOD, interpret=key.interpret)


planmod.register_plan_backend(planmod.PlanBackend(
    name="codegen_batch",
    # same eligibility as `codegen`; batch_native=True restricts it to
    # radius_kind="batch" keys (the planner enforces the gate)
    available=_codegen_available,
    build=_build_codegen_batch,
    description="batched-grid generated kernels for serving buckets: the "
                "stacked batch axis joins the Pallas grid (per-item radii in "
                "SMEM) instead of vmap-lifting the per-item kernel — one "
                "dispatch per pipeline stage for the whole bucket",
    batch_native=True,
))
