"""Fused Pallas kernels as projection-planner backends (DESIGN.md §2).

Importing this module registers the specialized executables with
``repro.core.plan`` (the planner imports it lazily on first ``make_plan``, so
``core`` never imports ``kernels`` at module load):

* ``fused_bilevel``  — ``bilevel_l1inf_pallas``  for ν = [(∞,1),(1,1)], 2-D
* ``fused_trilevel`` — ``trilevel_l1infinf_pallas`` for ν = [(∞,1),(∞,1),(1,1)], 3-D

Both are eligible on TPU, or anywhere under ``interpret=True`` (correctness
tests only — interpret mode is orders of magnitude slower than the jnp path,
so ``method="auto"`` will never pick them off-TPU, by measurement).
"""

from __future__ import annotations

import functools

from repro.core import plan as planmod

from .bilevel_l1inf import bilevel_l1inf_pallas
from .trilevel_l1infinf import trilevel_l1infinf_pallas

# the VPU-shaped outer θ-solve; kernels exist for "bisect" and "filter" and
# bisect has no data-dependent sweep count (stable latency for a served plan)
_OUTER_METHOD = "bisect"

_BILEVEL_LEVELS = (("inf", 1), ("1", 1))
_TRILEVEL_LEVELS = (("inf", 1), ("inf", 1), ("1", 1))


def _on_tpu_or_interpret(key: planmod.PlanKey) -> bool:
    # single-device workloads only: a mesh-sharded key routes to the sharded
    # schedule executor, not to a fused single-chip kernel
    return (key.device == "tpu" or key.interpret) and key.sharding is None


def _bilevel_available(key: planmod.PlanKey) -> bool:
    return (key.levels == _BILEVEL_LEVELS and len(key.shape) == 2
            and _on_tpu_or_interpret(key))


def _trilevel_available(key: planmod.PlanKey) -> bool:
    return (key.levels == _TRILEVEL_LEVELS and len(key.shape) == 3
            and _on_tpu_or_interpret(key))


def _build_bilevel(key: planmod.PlanKey):
    return functools.partial(bilevel_l1inf_pallas, method=_OUTER_METHOD,
                             interpret=key.interpret)


def _build_trilevel(key: planmod.PlanKey):
    return functools.partial(trilevel_l1infinf_pallas, method=_OUTER_METHOD,
                             interpret=key.interpret)


planmod.register_plan_backend(planmod.PlanBackend(
    name="fused_bilevel",
    available=_bilevel_available,
    build=_build_bilevel,
    description="Pallas bi-level l1,inf: colmax -> P1 kernel -> clip",
))

planmod.register_plan_backend(planmod.PlanBackend(
    name="fused_trilevel",
    available=_trilevel_available,
    build=_build_trilevel,
    description="Pallas tri-level l1,inf,inf: fused reduce -> P1 kernel -> apply",
))
