"""Generated fused kernels as a projection-planner backend (DESIGN.md §2/§4).

Importing this module registers the ``codegen`` and ``codegen_batch``
backends with ``repro.core.plan`` (the planner imports it lazily on first
``make_plan``, so ``core`` never imports ``kernels`` at module load): the
kernel code generator (``kernels/codegen``) lowers ANY unsharded norm design
the tiler accepts to a fused reduce → θ-solve → apply kernel pipeline —
eligible on TPU, or anywhere under ``interpret=True`` (correctness tests
only; interpret mode is orders of magnitude slower than the jnp path, so
``method="auto"`` will never pick it off-TPU, by measurement).
``codegen_batch`` is the serving-bucket variant: batch-native (the stacked
batch axis joins the Pallas grid, per-item radii in SMEM), competing only on
``radius_kind="batch"`` plan keys.

The hand-written fused kernels (``bilevel_l1inf.py``/``trilevel_l1infinf.py``)
are no longer registered as backends: they are the *golden references* the
codegen equality tests pin against (``tests/test_codegen.py``) and the
baseline of ``benchmarks/run.py --only codegen``.
"""

from __future__ import annotations

from repro.core import plan as planmod

from . import codegen

# the outer θ-solve of generated kernels: "bisect" has a VMEM kernel and no
# data-dependent sweep count (stable latency for a served plan)
_OUTER_METHOD = "bisect"


def _codegen_available(key: planmod.PlanKey) -> bool:
    # single-device workloads only: a mesh-sharded key routes to the sharded
    # schedule executor, not to a fused single-chip kernel.  Training keys
    # (key.grad) are eligible too: the generated kernels carry a generated
    # residual-VJP backward (kernels/codegen/backward.py) — no sort-oracle
    # recompute — so for grad keys the autotuner times them under
    # value_and_grad like any other candidate.
    if key.sharding is not None or not (key.device == "tpu" or key.interpret):
        return False
    return codegen.supported(key.shape, key.levels, key.dtype)


def _build_codegen(key: planmod.PlanKey):
    # build_tuned wires the measured block-size autotuner into make_plan:
    # the small candidate grid around the heuristic TilePlan is shot out the
    # same way method="auto" shoots out backends, and the winner is cached
    # per (canonical shape, dtype, device, interpret). In interpret mode the
    # measurement is skipped (block sizes change no machine behaviour there)
    # and the heuristic default is used.
    return codegen.build_tuned(key.shape, key.levels, key.dtype,
                               method=_OUTER_METHOD, interpret=key.interpret)


planmod.register_plan_backend(planmod.PlanBackend(
    name="codegen",
    available=_codegen_available,
    build=_build_codegen,
    description="generated fused Pallas kernels: one streaming reduce pass "
                "-> VMEM theta-solve -> fused apply epilogue (kernels/codegen)",
))


def _build_codegen_batch(key: planmod.PlanKey):
    return codegen.build_batched(key.shape, key.levels, key.dtype,
                                 method=_OUTER_METHOD, interpret=key.interpret)


planmod.register_plan_backend(planmod.PlanBackend(
    name="codegen_batch",
    # same eligibility as `codegen`; batch_native=True restricts it to
    # radius_kind="batch" keys (the planner enforces the gate)
    available=_codegen_available,
    build=_build_codegen_batch,
    description="batched-grid generated kernels for serving buckets: the "
                "stacked batch axis joins the Pallas grid (per-item radii in "
                "SMEM) instead of vmap-lifting the per-item kernel — one "
                "dispatch per pipeline stage for the whole bucket",
    batch_native=True,
))


def _sharded_codegen_available(key: planmod.PlanKey) -> bool:
    # the mesh executor's gates (scalar radius, forward key, live mesh) plus
    # the codegen ones (TPU or interpret; the shard-local schedule must have
    # a splice-compatible sharding and a VMEM tiling — distributed.shardable)
    if (key.sharding is None or key.radius_kind != "scalar" or key.grad
            or not (key.device == "tpu" or key.interpret)):
        return False
    mk = (key.sharding.mesh_axes, key.sharding.devices)
    if mk not in planmod._MESHES:
        return False
    from .codegen import distributed as dist

    return dist.shardable(key.shape, key.levels, key.sharding.spec,
                          planmod._MESHES[mk], key.dtype)


def _build_sharded_codegen(key: planmod.PlanKey):
    from repro.core import sharded as shmod

    mesh = planmod._MESHES[key.sharding.mesh_axes, key.sharding.devices]
    spec = key.sharding.spec
    levels = list(key.levels)
    interpret = key.interpret

    def fn(y, radius):
        return shmod.multilevel_project_sharded(
            y, levels, radius, mesh=mesh, spec=spec, method="auto",
            backend="codegen", interpret=interpret)

    return fn


planmod.register_plan_backend(planmod.PlanBackend(
    name="sharded_codegen",
    available=_sharded_codegen_available,
    build=_build_sharded_codegen,
    description="schedule executor under shard_map with the shard-local "
                "stages lowered through the fused codegen kernels: same "
                "collective plan as 'sharded', one streaming Pallas reduce "
                "and one fused apply epilogue per shard "
                "(kernels/codegen/distributed.py)",
))
