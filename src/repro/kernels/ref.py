"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ball


def colmax_ref(y: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(y), axis=0)


def clip_ref(y: jax.Array, u: jax.Array) -> jax.Array:
    return jnp.clip(y, -u[None, :].astype(y.dtype), u[None, :].astype(y.dtype))


def project_l1_ref(v: jax.Array, radius, method: str = "bisect") -> jax.Array:
    return ball.project_l1(v, radius, method=ball.resolve_method(method))


def bilevel_l1inf_ref(y: jax.Array, radius, method: str = "bisect") -> jax.Array:
    v = colmax_ref(y)
    u = project_l1_ref(v, radius, method=method)
    return clip_ref(y, u)


def trilevel_l1infinf_ref(y: jax.Array, radius, method: str = "bisect") -> jax.Array:
    """Tri-level ℓ1,∞,∞ oracle — the unfused core.multilevel recursion."""
    from repro.core import multilevel

    return multilevel.trilevel_l1infinf(y, radius, method=method)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Reference multi-head attention: q,k,v are (B, H, S, D) (H may differ for
    kv with GQA — callers repeat kv heads before this oracle)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned (decode-friendly)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
