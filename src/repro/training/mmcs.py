"""Mean Max Cosine Similarity — comparing learned SAE dictionaries.

MMCS is the standard dictionary-recovery metric for sparse autoencoders:
for every feature (column) of dictionary ``A``, find its best-matching
feature in ``B`` by absolute cosine similarity and average the matches,

    MMCS(A, B) = mean_i max_j |cos(a_i, b_j)|.

``|cos|`` makes the score invariant to per-feature sign flips, and the
max-over-columns makes it invariant to feature permutation — the two gauge
freedoms of a learned dictionary. The directional form is NOT symmetric when
the dictionaries differ (every A-feature finds a neighbour in B, not vice
versa); ``mmcs_sym`` averages both directions for a symmetric score. The
factory uses it to compare dictionaries across seeds/models/layers
(training/sae_factory.py), as the companion works do across RLHF'd vs base
models.

All functions accept dictionaries as ``(d, k)`` arrays: columns are features
(the decoder weight of models/sae.py's ``dict_template`` is ``(k, d)`` —
pass ``W.T``).
"""

from __future__ import annotations

import jax.numpy as jnp


def _unit_columns(a, eps):
    n = jnp.linalg.norm(a, axis=0, keepdims=True)
    return a / jnp.maximum(n, eps)


def mmcs(a, b, *, eps: float = 1e-9):
    """Directional MMCS(A, B): mean over A's columns of the best |cos| in B.

    ``a`` (d, ka), ``b`` (d, kb) — any float dtypes; computed in f32.
    Invariances: column permutation of either argument, per-column sign
    flips, per-column positive rescaling. MMCS(A, A) == 1 exactly (each
    column's best match is itself). Zero columns match nothing (their row of
    cosines is 0), dragging the mean down instead of poisoning it with NaNs.
    """
    a = _unit_columns(jnp.asarray(a, jnp.float32), eps)
    b = _unit_columns(jnp.asarray(b, jnp.float32), eps)
    cos = jnp.abs(a.T @ b)                     # (ka, kb)
    return jnp.mean(jnp.max(cos, axis=1))


def mmcs_sym(a, b, *, eps: float = 1e-9):
    """Symmetrized MMCS: (MMCS(A,B) + MMCS(B,A)) / 2."""
    return 0.5 * (mmcs(a, b, eps=eps) + mmcs(b, a, eps=eps))


def mmcs_table(dicts: dict, *, eps: float = 1e-9) -> dict:
    """Pairwise symmetric MMCS across named dictionaries.

    ``dicts`` maps run/model names to (d, k) arrays; returns
    ``{(name_i, name_j): float}`` for i < j in insertion order — the
    cross-run comparison grid of the factory's sweep reports.
    """
    names = list(dicts)
    out = {}
    for i, ni in enumerate(names):
        for nj in names[i + 1:]:
            out[(ni, nj)] = float(mmcs_sym(dicts[ni], dicts[nj], eps=eps))
    return out
