"""repro.training — jitted train step with grad accumulation + projection."""
from .mmcs import mmcs, mmcs_sym, mmcs_table  # noqa: F401
from .sae_factory import (  # noqa: F401
    SAEFactoryConfig, gsp_whole_network, harvest_activations,
    make_sae_train_step, run_factory, train_sae,
)
from .step import init_state, make_loss_fn, make_train_step, xent  # noqa: F401
