"""repro.training — jitted train step with grad accumulation + projection."""
from .step import init_state, make_loss_fn, make_train_step, xent  # noqa: F401
