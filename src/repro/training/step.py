"""The jitted training step: microbatch gradient accumulation (lax.scan),
per-layer remat (inside the models), AdamW, and the paper's projection hook.

``make_train_step(cfg, tcfg, api, n_groups)`` returns

    train_step(state, batch) -> (state, metrics)

  state = {"params", "opt", } ; batch = {"tokens": (n_micro, mb, S)}

Loss is next-token CE computed with ``take_along_axis`` (vocab-sharding
friendly: the logsumexp partial-reduces over the sharded vocab axis and GSPMD
lowers the target-logit gather to a masked local gather + all-reduce — see
``xent``; no (B,S,V) one-hot is ever materialized).

When projection is enabled and the step is not mesh-native, the optimizer
epilogue runs FUSED (``optim/fused_step.py``): AdamW update, multi-level
projection, and the param/master casts execute in one pass per matched leaf
instead of three separate sweeps (``fused="auto"`` — force with
``fused=True/False``).
"""

from __future__ import annotations

import functools
import re
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig, TrainConfig
from repro.core import multilevel
from repro.obs import jax_bridge
from repro.optim import adamw, fused_step
from repro.optim.projection_hook import _path_str, make_projection_hook


def xent(logits, targets):
    """logits (B,S,V) any float dtype; targets (B,S) int32. Mean nll in f32.

    take_along_axis (not a one-hot einsum): GSPMD lowers the vocab-axis gather
    on a model-sharded logits tensor to a masked local gather + all-reduce —
    O(B·S) bytes instead of materializing a (B,S,V) one-hot."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt)


def make_loss_fn(cfg: ArchConfig, api, *, impl: str, n_groups: int,
                 remat: bool, compute_dtype, act_spec=None, logits_spec=None):
    def loss_fn(params, tokens):
        cparams = jax.tree_util.tree_map(
            lambda p: p.astype(compute_dtype)
            if p.dtype in (jnp.float32, jnp.bfloat16) else p, params)
        kw = {"remat": remat, "act_spec": act_spec}
        if cfg.family not in ("ssm", "hybrid"):
            kw["impl"] = impl
        if cfg.family in ("dense", "moe", "vlm"):
            kw["n_groups"] = n_groups
        logits, aux = api.forward(cparams, tokens[:, :-1], cfg, **kw)
        if logits_spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        loss = xent(logits, tokens[:, 1:])
        if isinstance(aux, jax.Array) or (isinstance(aux, float) and aux):
            loss = loss + 0.01 * aux
        return loss

    return loss_fn


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, api, *,
                    impl: str = "chunked", n_groups: int = 1,
                    act_spec=None, logits_spec=None,
                    mesh=None, param_specs=None,
                    fused: bool | str = "auto",
                    telemetry_every: int = 0,
                    telemetry_marks: bool = False,
                    loss_fn: Callable = None) -> Callable:
    """Build the jitted projected train step (see module docstring).

    ``loss_fn(params, microbatch) -> scalar`` overrides the default LM
    next-token CE — the SAE factory passes the dictionary reconstruction loss
    and streams (n_micro, mb, d_model) activation batches through the same
    grad-accumulation scan, fused AdamW+project epilogue included
    (``batch["tokens"]`` is the per-step data leaf whatever its dtype/rank).

    ``telemetry_every > 0`` ships in-step telemetry to the obs registry
    through the host-callback bridge every that many steps (loss, grad norm,
    and — when projecting — per-leaf zero fraction and feasibility gap),
    batched in one ``lax.cond`` so off-cadence steps pay nothing.
    ``telemetry_marks=True`` additionally brackets the optimizer/projection
    epilogue with an *ordered* mark pair (``train_epilogue_seconds`` /
    ``train_projection_seconds`` histograms — the projection-time share of a
    step). Ordered callbacks serialize with the computation on EVERY step
    (they cannot ride the cadence cond), so marks are an opt-in deep-dive
    tool, priced separately by ``benchmarks/obs_overhead.py``. All of it
    rides :mod:`repro.obs.jax_bridge`, whose gate is trace-time static:
    with the bridge disabled the lowered step is bit-identical to
    ``telemetry_every=0`` (the overhead-off gate pins this).
    """
    compute_dtype = jnp.dtype(tcfg.compute_dtype)
    if loss_fn is None:
        loss_fn = make_loss_fn(cfg, api, impl=impl, n_groups=n_groups,
                               remat=tcfg.remat, compute_dtype=compute_dtype,
                               act_spec=act_spec, logits_spec=logits_spec)
    # single-pass epilogue: AdamW-update → project → cast fused per leaf
    # (optim/fused_step.py). "auto" = fused whenever projection is on and we
    # are not mesh-native (the sharded executor path keeps the hook, whose
    # shard_map placement the fused loop does not replicate yet).
    projecting = tcfg.projection is not None and tcfg.projection.enabled
    if fused == "auto":
        use_fused = projecting and mesh is None
    else:
        use_fused = bool(fused)
        if use_fused and mesh is not None:
            raise ValueError("fused=True is single-device/GSPMD only — the "
                             "mesh-native projection path needs fused='auto' "
                             "or fused=False")
    # plan the projection ONCE at step-build time (regex + backend resolution,
    # incl. method="auto" autotuning) — the per-step call is just the math.
    # mesh + param_specs make it mesh-native: sharded leaves project in place
    # under shard_map instead of relying on GSPMD (DESIGN.md §3)
    project = None if use_fused else make_projection_hook(
        tcfg.projection, mesh=mesh, param_specs=param_specs)

    emit_leaves = None
    if telemetry_every and projecting:
        # trace-time-static leaf matching (same rule as the hook); values
        # compute INSIDE the cond branch, so off-cadence steps pay nothing
        spec = tcfg.projection
        pat = re.compile(spec.pattern)
        need = sum(k for _, k in spec.levels)

        def _leaf_stats(w):
            x = w.astype(jnp.float32)
            if spec.transpose:
                x = jnp.swapaxes(x, -1, x.ndim - need) if need == 2 else \
                    jnp.transpose(x, tuple(range(x.ndim - need)) + tuple(
                        reversed(range(x.ndim - need, x.ndim))))
            fn = lambda v: multilevel.multilevel_norm(v, list(spec.levels))
            for _ in range(x.ndim - need):
                fn = jax.vmap(fn)
            worst = jnp.max(fn(x))
            return jnp.mean(w == 0), worst / spec.radius - 1.0

        def emit_leaves(params):
            def one(path, w):
                name = _path_str(path)
                if w.ndim >= need and pat.search(name):
                    zero_frac, gap = _leaf_stats(w)
                    jax_bridge.report("train_param_zero_frac", zero_frac,
                                      labels={"leaf": name})
                    jax_bridge.report("train_feasibility_gap", gap,
                                      labels={"leaf": name})
                return w

            jax.tree_util.tree_map_with_path(one, params)

    def train_step(state, batch):
        params = state["params"]
        tokens = batch["tokens"]              # (n_micro, mb, S)
        n_micro = tokens.shape[0]

        acc_dtype = (jnp.bfloat16 if tcfg.grad_allreduce_dtype == "bfloat16"
                     else jnp.float32)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)

        def micro(carry, toks):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, toks)
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dtype), gsum, grads)
            return (gsum, lsum + loss), None

        (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)),
                                            tokens)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        loss = loss_sum / n_micro

        if use_fused:
            # one pass per leaf: update → project (f32) → cast param/master
            if telemetry_marks:
                jax_bridge.mark("train_epilogue_start")
            new_params, new_opt, metrics = fused_step.fused_update(
                grads, state["opt"], params, tcfg)
            if telemetry_marks:
                jax_bridge.mark("train_epilogue_end")
        else:
            new_params, new_opt, metrics = adamw.update(grads, state["opt"],
                                                        params, tcfg)
            # the paper's constraint: project back onto the norm ball
            if telemetry_marks:
                jax_bridge.mark("train_projection_start")
            new_params = project(new_params, new_opt["step"])
            if telemetry_marks:
                jax_bridge.mark("train_projection_end")
            # keep the master copy consistent with the projected params
            if "master" in new_opt and projecting:
                new_opt = dict(new_opt)
                new_opt["master"] = jax.tree_util.tree_map(
                    lambda p, m: p.astype(m.dtype), new_params,
                    new_opt["master"])
        metrics = dict(metrics, loss=loss)
        if telemetry_every and jax_bridge.enabled():
            def _emit(op):
                loss_v, gnorm_v, ps = op
                jax_bridge.report("train_loss", loss_v)
                jax_bridge.report("train_grad_norm", gnorm_v)
                if emit_leaves is not None:
                    emit_leaves(ps)
                return jnp.zeros((), jnp.int32)

            jax.lax.cond(
                new_opt["step"] % telemetry_every == 0, _emit,
                lambda op: jnp.zeros((), jnp.int32),
                (loss, metrics["grad_norm"], new_params))
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(cfg: ArchConfig, tcfg: TrainConfig, api, key):
    from repro.models import params as PM
    tpl = api.template(cfg)
    params = PM.init_params(tpl, key, jnp.dtype(tcfg.param_dtype))
    opt = adamw.init(params, tcfg)
    return {"params": params, "opt": opt}
