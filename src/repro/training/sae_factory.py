"""Sparse-SAE training factory — the paper's headline application, end to end.

Three stages, each reusing the stack the previous PRs built:

1. **Harvest** (``data/activations.py``): run a configured LM from
   ``configs/`` over the deterministic token stream and shard per-layer
   residual/MLP activations to disk.
2. **Projected SAE training**: stream the shards back through
   ``DataPipeline`` into ``make_train_step(fused="auto")`` with the
   dictionary SAE (``models/sae.py``) — the encoder weight is projected onto
   the bi-/tri-level ball every optimizer step by the fused AdamW+project
   epilogue (single-device) or the §3 mesh executor (sharded params project
   in place). Learned dictionaries are compared across runs with MMCS
   (``training/mmcs.py``).
3. **GSP-style whole-network sparsification**: a training run whose
   projection spec matches *every* weight of the LM — each step projects
   every layer, with sharded leaves routed through the mesh executor
   (forced 8-device CPU mesh in CI; 1B–671B configs on real meshes).

``benchmarks/sae_factory.py`` drives stages 1–3 at miniature scale plus the
paper's §7.3 accuracy-vs-sparsity tables into ``BENCH_sae_factory.json``;
``launch/sae_factory.py`` is the CLI. Everything here is deterministic given
(arch, seeds): the data cursor is the step index, inits are PRNGKey-seeded.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import registry
from repro.configs.types import ProjectionSpec, TrainConfig
from repro.core import multilevel_norm
from repro.data import DataConfig, DataPipeline
from repro.data.activations import HarvestConfig, harvest, read_meta
from repro.models import params as PM, sae
from repro.optim import adamw
from repro.optim.projection_hook import matched_names, tree_sparsity
from repro.training import step as TS


# ------------------------------------------------------------------ stage 1/2
@dataclasses.dataclass(frozen=True)
class SAEFactoryConfig:
    """One factory run: which model, what to harvest, how to train the SAE."""
    arch: str = "stablelm-1.6b"
    smoke: bool = True               # reduced arch (CPU tests); False = full
    site: str = "resid"              # harvest site
    layers: Optional[Sequence[int]] = None   # None -> all layers
    harvest_steps: int = 4           # shards per layer
    seq_len: int = 16
    lm_batch: int = 4                # sequences per harvest step
    expansion: int = 4               # d_dict = expansion * d_model
    train_steps: int = 40
    sae_batch: int = 64              # rows per SAE optimizer step
    microbatch: int = 32
    lr: float = 1e-2
    radius: float = 1.0
    levels: tuple = (("inf", 1), (1, 1))     # bi-level l1,inf by default
    heads: int = 1                   # >1: head-structured dictionary (§6) —
                                     # 3-D encoder + tri-level projection
    method: str = "bisect"
    seed: int = 0


def effective_levels(fcfg: SAEFactoryConfig) -> tuple:
    """The norm design actually projected: a head-structured factory
    (``heads > 1``) upgrades the default bi-level design to the paper's §6
    tri-level ℓ1,∞,∞ (one ∞ level per head axis of the 3-D encoder); an
    explicit 3-axis ``fcfg.levels`` wins."""
    if fcfg.heads == 1 or sum(k for _, k in fcfg.levels) != 2:
        return tuple(fcfg.levels)
    return (("inf", 1),) + tuple(fcfg.levels)


def lm_for(fcfg: SAEFactoryConfig):
    """(cfg, api, params) for the harvest model, seeded by ``fcfg.seed``."""
    cfg = (registry.smoke_config(fcfg.arch) if fcfg.smoke
           else registry.get_arch(fcfg.arch))
    api = models.get(cfg)
    params = PM.init_params(api.template(cfg), jax.random.PRNGKey(fcfg.seed))
    return cfg, api, params


def harvest_activations(fcfg: SAEFactoryConfig, out_dir, params=None) -> dict:
    """Stage 1: run the LM, shard activations. Returns the manifest."""
    cfg, api, init = lm_for(fcfg)
    pipe = DataPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=fcfg.seq_len, global_batch=fcfg.lm_batch,
        microbatch=fcfg.lm_batch, seed=fcfg.seed))
    hcfg = HarvestConfig(site=fcfg.site, layers=fcfg.layers,
                         n_steps=fcfg.harvest_steps)
    return harvest(params if params is not None else init, cfg, pipe, out_dir,
                   hcfg=hcfg, forward=api.forward)


def sae_projection_spec(fcfg: SAEFactoryConfig) -> ProjectionSpec:
    """The per-step constraint: encoder columns (features) live on the ball.

    ``transpose=True`` groups by dictionary feature (paper §7.3 — the SAE's
    feature-selection orientation), exactly like the table experiments. With
    ``heads > 1`` the encoder is 3-D and the transposed view is
    (d_per_head, heads, d_in): the tri-level design aggregates ∞ over the
    per-head slots, ∞ over heads, then solves ℓ1 over d_in — zeroing whole
    heads, not just whole features.
    """
    return ProjectionSpec(pattern=r"enc/w", levels=effective_levels(fcfg),
                          radius=fcfg.radius, every=1, method=fcfg.method,
                          transpose=True)


def sae_train_config(fcfg: SAEFactoryConfig) -> TrainConfig:
    return TrainConfig(
        microbatch=fcfg.microbatch, lr=fcfg.lr, weight_decay=0.0,
        grad_clip=1.0, warmup=2, total_steps=max(fcfg.train_steps, 2),
        master_dtype="", compute_dtype="float32", remat=False,
        projection=sae_projection_spec(fcfg), seed=fcfg.seed)


def init_sae_state(d_in: int, d_dict: int, tcfg: TrainConfig, key, *,
                   heads: int = 1):
    params = PM.init_params(sae.dict_template(d_in, d_dict, heads=heads), key,
                            jnp.dtype(tcfg.param_dtype))
    return {"params": params, "opt": adamw.init(params, tcfg)}


def make_sae_train_step(tcfg: TrainConfig, *, l1: float = 0.0,
                        fused="auto", mesh=None, param_specs=None):
    """The projected dictionary-SAE step: ``make_train_step`` with the
    reconstruction loss — fused AdamW+project epilogue on the single-device
    path, mesh-native in-place projection when ``mesh``/``param_specs`` are
    given."""
    return TS.make_train_step(
        None, tcfg, None, fused=fused, mesh=mesh, param_specs=param_specs,
        loss_fn=lambda p, xb: sae.dict_loss(p, xb.astype(jnp.float32), l1=l1))


def train_sae(harvest_dir, layer: int, fcfg: SAEFactoryConfig, *,
              seed: Optional[int] = None) -> dict:
    """Stage 2 for one layer: stream shards into projected SAE training.

    Returns ``{"params", "metrics", "dictionary", "sparsity"}`` — the
    dictionary is the decoder weight transposed to (d_model, d_dict), ready
    for ``mmcs``.
    """
    meta = read_meta(harvest_dir)
    d_in = meta["d_model"]
    d_dict = fcfg.expansion * d_in
    seed = fcfg.seed if seed is None else seed
    tcfg = sae_train_config(fcfg)
    pipe = DataPipeline(DataConfig(
        vocab=1, seq_len=0, global_batch=fcfg.sae_batch,
        microbatch=fcfg.microbatch, activation_dir=str(harvest_dir),
        activation_layer=layer))
    state = init_sae_state(d_in, d_dict, tcfg, jax.random.PRNGKey(seed),
                           heads=fcfg.heads)
    step = jax.jit(make_sae_train_step(tcfg))
    last = {}
    for i in range(fcfg.train_steps):
        state, m = step(state, {"tokens": jnp.asarray(pipe.batch(i))})
    last = {k: float(v) for k, v in m.items()}
    params = state["params"]
    eval_rows = jnp.asarray(pipe.batch(0)).reshape(-1, d_in).astype(jnp.float32)
    diag = {k: float(v) for k, v in sae.dict_metrics(params, eval_rows).items()}
    spec = sae_projection_spec(fcfg)
    return {
        "params": params,
        "metrics": dict(last, **diag),
        # head-structured dec/w is (heads, d_dict//heads, d_in): flatten the
        # head axes back to d_dict before the (d_model, d_dict) orientation
        "dictionary": np.asarray(params["dec"]["w"]).reshape(-1, d_in).T,
        "sparsity": {k: float(v)
                     for k, v in tree_sparsity(params, spec).items()},
    }


def run_factory(fcfg: SAEFactoryConfig, workdir, *, seeds=(0, 1),
                lm_params=None) -> dict:
    """Harvest once, train one SAE per (layer, seed), cross-compare with MMCS.

    The per-layer MMCS across seeds is the factory's headline consistency
    number (dictionaries learned from the same activations should agree up to
    permutation/sign — exactly MMCS's invariances). ``lm_params`` harvests
    from a trained checkpoint's weights instead of the seeded init (the CLI's
    ``--checkpoint``).
    """
    from repro.training.mmcs import mmcs_sym

    meta = harvest_activations(fcfg, workdir, params=lm_params)
    out = {"meta": meta, "layers": {}}
    for layer in meta["layers"]:
        runs = {s: train_sae(workdir, layer, fcfg, seed=s) for s in seeds}
        pairs = {}
        slist = list(seeds)
        for i, a in enumerate(slist):
            for b in slist[i + 1:]:
                pairs[f"seed{a}_vs_seed{b}"] = float(mmcs_sym(
                    runs[a]["dictionary"], runs[b]["dictionary"]))
        out["layers"][layer] = {
            "mmcs": pairs,
            "metrics": {s: runs[s]["metrics"] for s in seeds},
            "sparsity": {s: runs[s]["sparsity"] for s in seeds},
        }
    return out


# ------------------------------------------------------------------- stage 3
def constraint_report(params, spec: ProjectionSpec) -> dict:
    """Max multilevel-norm violation over matched leaves (0 == feasible).

    Leading (stacked) axes are enumerated exactly like the hook's vmap, so a
    single infeasible layer of a scanned stack can't hide in an aggregate.
    """
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    levels = list(spec.levels)
    report = {}

    def norm_of(w):
        if spec.transpose:
            w = jnp.swapaxes(w, -1, -2) if need == 2 else jnp.transpose(
                w, tuple(range(w.ndim - need)) + tuple(
                    reversed(range(w.ndim - need, w.ndim))))
        fn = lambda x: multilevel_norm(x, levels)
        for _ in range(w.ndim - need):
            fn = jax.vmap(fn)
        return jnp.max(jnp.atleast_1d(fn(w)))

    def one(path, w):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if hasattr(w, "ndim") and w.ndim >= need and pat.search(name):
            report[name] = float(norm_of(jnp.asarray(w, jnp.float32)))
        return w

    jax.tree_util.tree_map_with_path(one, params)
    viol = max((v - spec.radius for v in report.values()), default=0.0)
    return {"norms": report, "max_violation": max(viol, 0.0),
            "feasible": viol <= spec.radius * 1e-3 + 1e-5}


def gsp_whole_network(arch: str = "stablelm-1.6b", *, mesh=None,
                      steps: int = 2, radius: float = 3.0,
                      pattern: str = r".*", microbatch: int = 2,
                      seq_len: int = 17, seed: int = 0) -> dict:
    """GSP-style whole-network sparsification: project EVERY weight per step.

    ``pattern=r".*"`` matches every >=2-D parameter of the LM — embeddings,
    attention projections (trailing (heads, head_dim) axes: the paper's §6
    head-structured sparsity), and MLP weights alike. With ``mesh`` given,
    leaves whose trailing axes are sharded project in place through the §3
    schedule executor under shard_map (no gather); the rest take the vmapped
    single-device path. Returns per-leaf column sparsity and a feasibility
    report — the CI ``sae`` job runs this on a forced 8-device CPU mesh.
    """
    from repro.parallel import sharding as SH

    cfg = registry.smoke_config(arch)
    api = models.get(cfg)
    proj = ProjectionSpec(pattern=pattern, radius=radius, every=1,
                          method="bisect")
    tcfg = TrainConfig(microbatch=microbatch, lr=1e-3, warmup=2,
                       total_steps=max(steps, 2), master_dtype="",
                       remat=False, projection=proj, seed=seed)
    state = TS.init_state(cfg, tcfg, api, jax.random.PRNGKey(seed))
    pspecs = None
    if mesh is not None:
        tpl = api.template(cfg)
        pspecs = PM.param_specs(tpl, SH.param_rules(mesh, fsdp=True),
                                SH.mesh_shape_dict(mesh))
        ospecs = adamw.state_specs(pspecs, tpl, tcfg)
        state = jax.device_put(state, SH.named(
            mesh, {"params": pspecs, "opt": ospecs}))
    step = jax.jit(TS.make_train_step(cfg, tcfg, api, impl="naive",
                                      mesh=mesh, param_specs=pspecs))
    pipe = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                   global_batch=2 * microbatch,
                                   microbatch=microbatch, seed=seed))
    for i in range(steps):
        state, metrics = step(state, {"tokens": jnp.asarray(pipe.batch(i))})
    params = jax.tree_util.tree_map(np.asarray, state["params"])
    names = matched_names(params, proj)
    rep = constraint_report(params, proj)
    sp = tree_sparsity(params, proj)
    return {
        "n_projected": len(names),
        "n_devices": int(mesh.devices.size) if mesh is not None else 1,
        "feasible": rep["feasible"],
        "max_violation": rep["max_violation"],
        "mean_col_sparsity": float(np.mean([float(v) for v in sp.values()])),
        "per_leaf_sparsity": {k: float(v) for k, v in sp.items()},
        "loss": float(metrics["loss"]),
    }
