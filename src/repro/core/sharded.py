"""Mesh-parallel bi-level projection — Proposition 6.4 on a TPU mesh.

The bi-level split makes the distributed projection almost communication-free:
with a weight matrix sharded column-wise over mesh axis ``axis_name``,

    local:   v_loc  = ‖·‖_q of the LOCAL columns             (no comm)
    gather:  v      = all_gather(v_loc)                      (m × 4 bytes — tiny)
    local:   u      = P^p_η(v)  (replicated tiny solve)      (no comm)
    local:   X_loc  = P^q_{u_j}(Y_loc)                       (no comm)

versus the exact projection which needs the full matrix on one device
(nm × 4 bytes of collective traffic). The all-gather'd payload is a factor n
smaller — this is the paper's "exponential parallel speedup" realized as a
collective-bytes reduction; DESIGN.md §3 ("The sharded bi-level split: a
collective-bytes argument") derives the bound.

These functions are written for use inside ``jax.shard_map``; the
``*_spmd`` wrappers build the shard_map for a given mesh. When the columns of
the target tensor are *not* sharded (or the mesh axis doesn't divide them),
the plain ``core.bilevel`` functions are used — GSPMD then keeps everything
local because all ops are elementwise/reduce along unsharded axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import ball
from .bilevel import _inner_project_cols


def bilevel_project_sharded(y_local: jax.Array, radius, p=1, q=jnp.inf,
                            *, axis_name: str, method: str = "sort") -> jax.Array:
    """Body to run under shard_map; ``y_local`` is the (n, m_local) shard."""
    v_local = ball.norm_reduce(y_local, q, axes=0)              # (m_local,)
    v = jax.lax.all_gather(v_local, axis_name, tiled=True)      # (m,) replicated
    u = ball.project_ball(v, p, radius, method=method)          # tiny, replicated
    idx = jax.lax.axis_index(axis_name)
    m_local = y_local.shape[1]
    u_local = jax.lax.dynamic_slice_in_dim(u, idx * m_local, m_local)
    return _inner_project_cols(y_local, q, u_local, method)


def make_sharded_bilevel(mesh, axis_name: str, p=1, q=jnp.inf, method: str = "sort"):
    """shard_map'd bi-level projection: columns (axis 1) sharded over axis_name.

    ``method="auto"`` autotunes the replicated outer θ-solve per gathered
    aggregate length (the m of the first call) — resolved OUTSIDE shard_map,
    once, so the per-call body stays collective-only.
    """
    if method != "auto":
        method = ball.resolve_method(method)  # fail at build time, not in shard_map
    resolved = {}

    def fn(y, radius):
        if method == "auto":
            from . import plan as _plan
            key = (y.shape[1], jnp.asarray(y).dtype.name)
            if key not in resolved:  # autotune once per (length, dtype)
                resolved[key] = _plan.best_l1_method(key[0], key[1])
            meth = resolved[key]
        else:
            meth = method
        body = functools.partial(
            bilevel_project_sharded, p=p, q=q, axis_name=axis_name, method=meth
        )
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(None, axis_name), P()),
            out_specs=P(None, axis_name),
        )(y, jnp.asarray(radius, jnp.float32))
    return fn


def trilevel_project_sharded(y_local: jax.Array, radius, *, axis_name: str,
                             method: str = "sort") -> jax.Array:
    """Sharded tri-level ℓ1,∞,∞ for (c, n, m_local) tensors (experts/heads last)."""
    v2 = jnp.max(jnp.abs(y_local), axis=0)                      # (n, m_local)
    v1_local = jnp.max(v2, axis=0)                              # (m_local,)
    v1 = jax.lax.all_gather(v1_local, axis_name, tiled=True)    # (m,)
    u1 = ball.project_l1(v1, radius, method=method)
    idx = jax.lax.axis_index(axis_name)
    m_local = y_local.shape[-1]
    u1_local = jax.lax.dynamic_slice_in_dim(u1, idx * m_local, m_local)
    v2_c = jnp.minimum(v2, u1_local[None, :])                   # P^inf per column
    return jnp.clip(y_local, -v2_c[None, :, :], v2_c[None, :, :])
