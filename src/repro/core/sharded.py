"""Mesh-native schedule executor — Proposition 6.4 on a device mesh, for ANY ν.

The compiled schedule of ``core.schedule`` maps onto a mesh step by step
(DESIGN.md §3 derives the collective-bytes bound):

    ReduceLevel  — local norm-reduce; ONE collective combine (psum / pmax)
                   only when the level aggregates a sharded axis, and the
                   payload is the already-reduced aggregate, not the tensor
    OuterSolve   — all-gather of the FINAL aggregate (tiny, and only if a
                   sharded axis survives every reduce), replicated θ-solve,
                   local re-slice of the per-group radii
    ApplyGroup   — local: ℓ∞ is a clip, ℓ2 rescales by the saved (already
                   global) group norm; an ℓ1 apply whose group spans the mesh
                   runs a distributed bisection on θ (64 tiny φ-psums)

``multilevel_project_sharded`` is the full-array entry point: it pads uneven
shards with zeros (exact for every supported norm — zero entries are fixed
points of all three projections), runs the schedule under ``shard_map``, and
slices the result back. ``bilevel_project_sharded`` /
``trilevel_project_sharded`` — the two hand-written specials this module used
to consist of — survive as thin wrappers that build the equivalent schedule
body for use inside an existing ``shard_map``.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.obs import profile as obs_profile

from . import ball
from . import schedule as sched_mod

try:  # jax >= 0.5 exports it at the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent import
    from jax.experimental.shard_map import shard_map

_BISECT_ITERS = 64


def parse_spec(spec, ndim: int, mesh) -> Optional[Tuple[Optional[str], ...]]:
    """THE parser of PartitionSpec entries for the schedule executor (the
    planner's ``canonical_sharding`` and the projection hook delegate here).

    Returns the per-tensor-axis mesh axis name padded to ``ndim``, or ``None``
    when an entry shards one tensor axis over several mesh axes — supported
    by GSPMD but not by this executor, so callers fall back. A name that is
    not a mesh axis at all is a caller bug and raises immediately.
    """
    entries = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    names = []
    for entry in entries[:ndim]:
        if entry is None:
            names.append(None)
            continue
        if isinstance(entry, (tuple, list)):
            if len(entry) != 1:
                return None  # one mesh axis per tensor axis only
            entry = entry[0]
        if entry not in mesh.shape:
            raise ValueError(
                f"spec names mesh axis {entry!r} but mesh has "
                f"{tuple(mesh.shape)}")
        names.append(str(entry))
    return tuple(names)


def _spec_axis_names(spec, ndim: int, mesh) -> Tuple[Optional[str], ...]:
    """Strict :func:`parse_spec`: multi-mesh-axis entries are an error here
    (the executor cannot run them and has nothing to fall back to)."""
    names = parse_spec(spec, ndim, mesh)
    if names is None:
        raise ValueError(
            f"spec {tuple(spec)!r} shards a tensor axis over multiple mesh "
            "axes: the schedule executor supports one mesh axis per tensor "
            "axis")
    return names


def _grouped_l1_collective(y: jax.Array, radii: jax.Array, axes,
                           axis_names: Tuple[str, ...],
                           group_sum: jax.Array) -> jax.Array:
    """Distributed grouped-ℓ1 apply: each group spans mesh axes ``axis_names``.

    Bisection on the soft-threshold θ (DESIGN.md §4's VPU-shaped solver) where
    every φ(θ) evaluation is a local partial sum plus one tiny psum over the
    group count — the group's data never moves. ``group_sum`` is the saved
    global ℓ1 aggregate, giving the inside-the-ball test for free.
    """
    a = jnp.abs(y)
    hi = jax.lax.pmax(jnp.max(a, axis=axes), axis_names)
    # == 0 (hi >= 0), but derived from hi so shard_map's replication checker
    # sees the same rep type for both loop carries
    lo = jnp.minimum(hi, 0.0)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - jnp.expand_dims(mid, axes), 0.0),
                      axis=axes)
        phi = jax.lax.psum(phi, axis_names)
        too_small = phi > radii
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
    theta = jnp.where(group_sum <= radii, 0.0,
                      jnp.maximum(0.5 * (lo + hi), 0.0))
    return jnp.sign(y) * jnp.maximum(a - jnp.expand_dims(theta, axes), 0.0)


def make_schedule_body(sched: sched_mod.Schedule,
                       axis_names: Sequence[Optional[str]],
                       method: str = "sort"):
    """Build the shard_map body ``(y_local, radius) -> x_local`` for a schedule.

    ``axis_names[a]`` is the mesh axis the a-th tensor axis is sharded over
    (None = unsharded/local). The body is pure collective-and-local code —
    method resolution happens here, at build time, never inside the trace.
    """
    method = ball.resolve_method(method)
    b = sched.batch_dims

    def body(y_loc, radius):
        inputs = [y_loc]
        aggs = []
        stage_names = [tuple(axis_names)]
        for t, red in enumerate(sched.reduces):
            cur, names = inputs[-1], stage_names[-1]
            coll = tuple(names[a] for a in red.axes if names[a])
            with obs_profile.stage_scope(red, t):
                if red.norm == "1":
                    v = jnp.sum(jnp.abs(cur), axis=red.axes)
                    v = jax.lax.psum(v, coll) if coll else v
                elif red.norm == "2":
                    s = jnp.sum(jnp.square(cur), axis=red.axes)
                    v = jnp.sqrt(jax.lax.psum(s, coll) if coll else s)
                else:
                    v = jnp.max(jnp.abs(cur), axis=red.axes)
                    v = jax.lax.pmax(v, coll) if coll else v
            aggs.append(v)
            inputs.append(v)
            stage_names.append(tuple(
                n for a, n in enumerate(names) if a not in red.axes))

        # ---- OuterSolve: gather the surviving sharded axes (tiny), solve
        # replicated, slice the local radii back out ---------------------- #
        top, names = inputs[-1], stage_names[-1]
        local_sizes = top.shape
        with obs_profile.stage_scope(sched.solve):
            g = top
            for ax in range(b, len(names)):
                if names[ax]:
                    g = jax.lax.all_gather(g, names[ax], axis=ax, tiled=True)
            w = sched_mod.solve_outer(g, sched.solve.norm, radius, b, method)
            for ax in range(b, len(names)):
                if names[ax]:
                    idx = jax.lax.axis_index(names[ax])
                    w = jax.lax.dynamic_slice_in_dim(
                        w, idx * local_sizes[ax], local_sizes[ax], axis=ax)

        # ---- backward sweep: applies stay local (clip / saved-norm rescale);
        # only a mesh-spanning l1 group needs the distributed θ-solve ------ #
        for i, app in zip(reversed(range(len(aggs))), sched.applies):
            names = stage_names[i]
            coll = tuple(names[a] for a in app.axes if names[a])
            with obs_profile.stage_scope(app, i):
                if app.norm == "1" and coll:
                    w = _grouped_l1_collective(inputs[i], w, app.axes, coll,
                                               aggs[i])
                else:
                    w = sched_mod.apply_group(inputs[i], app.norm, w,
                                              app.axes, aggs[i], method)
        return w

    return body


def _resolve_sharded_method(method: str, sched: sched_mod.Schedule,
                            dtype) -> str:
    """``method="auto"`` for the mesh executor: autotune the replicated outer
    θ-solve on the gathered final-aggregate length (generic backends only —
    resolved at build time, outside shard_map; memoised by the planner)."""
    if method != "auto":
        return ball.resolve_method(method)
    from . import plan as _plan

    return _plan.best_l1_method(sched.solve_size, dtype)


def multilevel_project_sharded(y: jax.Array, levels, radius, *, mesh, spec,
                               method: str = "sort",
                               batch_dims: int = 0,
                               backend: str = "jnp",
                               interpret: bool = False) -> jax.Array:
    """MP^ν on a mesh: execute the compiled schedule under ``shard_map``.

    ``spec`` is the PartitionSpec of ``y`` over ``mesh`` (any sharded-axis
    position — aggregated, group, or batch axes may all be sharded; at most
    one mesh axis per tensor axis). The leading ``batch_dims`` axes are
    carried through as independent projections (the training hook's stacked
    layers/experts). Mesh axes that do not divide their tensor axis are
    handled by zero-padding (exact: zeros are fixed points of every level).

    ``method`` picks the θ-solver for the replicated outer solve and any
    local ℓ1 applies (``"auto"`` autotunes on the gathered aggregate length);
    a mesh-spanning ℓ1 group always uses the distributed bisection.

    ``backend`` picks the shard-local stage implementation: ``"jnp"`` (the
    schedule body above) or ``"codegen"`` — the fused Pallas kernels of
    ``kernels/codegen`` running inside the shard_map body, with the same
    collective plan spliced between them (``interpret`` lowers those kernels
    in interpreter mode off-TPU). Gate ``"codegen"`` with
    ``kernels.codegen.distributed.shardable`` — ineligible designs raise.
    """
    if backend not in ("jnp", "codegen"):
        raise ValueError(f"unknown sharded backend {backend!r}: "
                         "expected 'jnp' or 'codegen'")
    y = jnp.asarray(y)
    sched = sched_mod.compile_schedule(y.shape, levels, batch_dims)
    if not isinstance(spec, P):
        spec = P(*spec)
    names = _spec_axis_names(spec, y.ndim, mesh)
    meth = _resolve_sharded_method(method, sched, y.dtype)

    pad = [(0, (-d) % mesh.shape[n] if n else 0) for d, n in zip(y.shape, names)]
    padded = jnp.pad(y, pad) if any(p for _, p in pad) else y
    if padded.shape != y.shape:
        sched = sched_mod.compile_schedule(padded.shape, levels, batch_dims)

    if backend == "codegen":
        from repro.kernels.codegen import distributed as _dist

        body = _dist.make_codegen_schedule_body(
            sched, names, mesh, y.dtype, method=meth, interpret=interpret)
    else:
        body = make_schedule_body(sched, names, method=meth)
    in_spec = P(*names)
    # check_rep=False: the generic θ-solvers run while/fori loops (filter's
    # active-set sweep, bisect's fixed iteration) that the replication checker
    # has no rules for — it rejects them even though every carry is in fact
    # uniformly replicated after the gather. Correctness is pinned by the
    # sharded-vs-single-device equality tests across all registered methods.
    out = shard_map(body, mesh=mesh, in_specs=(in_spec, P()),
                    out_specs=in_spec,
                    check_rep=False)(padded, jnp.asarray(radius, y.dtype))
    if out.shape != y.shape:
        out = out[tuple(slice(0, d) for d in y.shape)]
    return out


# --------------------------------------------------------------------------- #
# The two historical specials — thin wrappers over the schedule body/executor
# --------------------------------------------------------------------------- #


def bilevel_project_sharded(y_local: jax.Array, radius, p=1, q=jnp.inf,
                            *, axis_name: str, method: str = "sort") -> jax.Array:
    """Bi-level body to run under shard_map; ``y_local`` is the (n, m_local)
    shard, columns sharded over ``axis_name``. Wrapper over the schedule body
    for ν = [(q, 1), (p, 1)]; requires even shards (the full-array
    ``multilevel_project_sharded`` pads uneven ones). The filter/bisect
    θ-solvers need the enclosing shard_map built with ``check_rep=False``
    (their while/fori loops have no replication rules — the executor does
    this for you)."""
    sched = sched_mod.compile_schedule(y_local.shape, [(q, 1), (p, 1)])
    body = make_schedule_body(sched, (None, axis_name), method=method)
    return body(y_local, radius)


def trilevel_project_sharded(y_local: jax.Array, radius, *, axis_name: str,
                             method: str = "sort") -> jax.Array:
    """Sharded tri-level ℓ1,∞,∞ body for (c, n, m_local) tensors (experts or
    heads last). Wrapper over the schedule body; even shards only."""
    sched = sched_mod.compile_schedule(
        y_local.shape, [(jnp.inf, 1), (jnp.inf, 1), (1, 1)])
    body = make_schedule_body(sched, (None, None, axis_name), method=method)
    return body(y_local, radius)


def _check_divides(m: int, mesh, axis_name: str, what: str) -> None:
    size = mesh.shape[axis_name]
    if m % size:
        raise ValueError(
            f"{what}: sharded axis of extent {m} is not divisible by mesh "
            f"axis {axis_name!r} of size {size} — the per-device slice of the "
            "outer solve would silently be wrong. Use "
            "multilevel_project_sharded, which zero-pads uneven shards.")


def make_sharded_bilevel(mesh, axis_name: str, p=1, q=jnp.inf,
                         method: str = "sort"):
    """shard_map'd bi-level projection: columns (axis 1) sharded over
    ``axis_name``. Delegates to the schedule executor, so ``method="auto"``
    autotunes the replicated outer θ-solve exactly like every other design.
    Validates shard evenness with a clear error at call time."""
    if method != "auto":
        method = ball.resolve_method(method)  # fail at build time

    def fn(y, radius):
        _check_divides(y.shape[1], mesh, axis_name, "make_sharded_bilevel")
        return multilevel_project_sharded(
            y, [(q, 1), (p, 1)], radius, mesh=mesh, spec=P(None, axis_name),
            method=method)

    return fn


def make_sharded_trilevel(mesh, axis_name: str, method: str = "sort"):
    """shard_map'd tri-level ℓ1,∞,∞: last axis sharded over ``axis_name``.
    The ``method="auto"`` path resolves through the planner like the bi-level
    builder (the historical asymmetry is gone — both are schedule wrappers)."""
    if method != "auto":
        method = ball.resolve_method(method)

    def fn(y, radius):
        _check_divides(y.shape[-1], mesh, axis_name, "make_sharded_trilevel")
        return multilevel_project_sharded(
            y, [(jnp.inf, 1), (jnp.inf, 1), (1, 1)], radius, mesh=mesh,
            spec=P(None, None, axis_name), method=method)

    return fn


def sharded_collective_bytes(shape, levels, spec, mesh,
                             itemsize: int = 4) -> dict:
    """Collective payload of this design on this mesh vs gather-and-project
    (the generalized DESIGN.md §3 argument; used by ``benchmarks.run --only
    sharded``)."""
    if not isinstance(spec, P):
        spec = P(*spec)
    names = _spec_axis_names(spec, len(shape), mesh)
    return sched_mod.sharded_collective_bytes(
        tuple(shape), levels, names,
        {n: mesh.shape[n] for n in mesh.axis_names}, itemsize)
