"""Structured-sparsity masks + double-descent support (paper Appendix B, Alg 8).

After a projection, whole columns (groups) are exactly zero. ``column_mask``
extracts the kept-column indicator; ``sparsity`` reports the paper's metric
(% of columns entirely zeroed). ``apply_mask`` freezes zeros for the second
descent of the double-descent schedule (mask ⊙ weights and mask ⊙ grads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def column_mask(x: jax.Array, axis: int = 0, tol: float = 0.0) -> jax.Array:
    """1.0 where the column (reduced over ``axis``) has any surviving weight."""
    alive = jnp.max(jnp.abs(x), axis=axis) > tol
    return alive.astype(x.dtype)


def sparsity(x: jax.Array, axis: int = 0, tol: float = 0.0) -> jax.Array:
    """Paper's sparsity score: % of columns set entirely to zero."""
    alive = jnp.max(jnp.abs(x), axis=axis) > tol
    return 100.0 * (1.0 - jnp.mean(alive.astype(jnp.float32)))


def element_sparsity(x: jax.Array, tol: float = 0.0) -> jax.Array:
    """% of individual weights that are zero (unstructured sparsity)."""
    return 100.0 * jnp.mean((jnp.abs(x) <= tol).astype(jnp.float32))


def mask_tree(params, axis: int = 0, tol: float = 0.0):
    """Column-mask every >=2-D leaf of a param pytree (1-D leaves get ones)."""
    def one(p):
        if p.ndim >= 2:
            m = column_mask(p, axis=axis, tol=tol)
            return jnp.broadcast_to(jnp.expand_dims(m, axis), p.shape)
        return jnp.ones_like(p)
    return jax.tree_util.tree_map(one, params)


def apply_mask(tree, masks):
    """Elementwise freeze: used on both weights and grads in descent #2."""
    return jax.tree_util.tree_map(lambda p, m: p * m, tree, masks)
