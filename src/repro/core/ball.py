"""Vector norm-ball projections — the primitives every level of the multi-level
projection is built from.

All functions are pure JAX (jit/vmap/grad-safe unless noted), operate on the
*last* axis of the input unless stated otherwise, and accept a scalar or
broadcastable ``radius``.

Three ℓ1 algorithms are provided (see DESIGN.md §4 — hardware adaptation):

* ``project_l1_sort``   — sort + prefix-sum threshold (Duchi et al. / Held et al.).
  O(n log n) work, O(log n) depth. Exact.
* ``project_l1_bisect`` — bisection on the soft-threshold θ. O(k·n) work with k fixed
  iterations, O(k log n) depth, only elementwise ops + reductions: the TPU/Pallas
  friendly variant. Accurate to ~2^-k of the value range.
* ``project_l1_filter`` — Michelot/Condat filtering: a fixed-point iteration on θ
  over a shrinking active set (masking, no sorting). O(n) expected work, converges
  in a handful of sweeps on typical data. Exact at the fixed point. The
  ``lax.while_loop`` only finds the active set (on stopped gradients); θ is
  recomputed from it in closed form, so the backend is reverse-mode
  differentiable like the others.

All reduce to the simplex projection of |y| followed by sign restoration.

Backend registry
----------------
The θ-solvers live in a registry keyed by method name; ``resolve_method()``
canonicalizes (and validates) a user-supplied name, and ``register_l1_method()``
adds a backend in one call — downstream modules (bilevel, multilevel, sharded,
kernels, optim) never enumerate method names themselves. Likewise the per-norm
projection/reduction dispatch lives in tables here (``canonical_norm`` +
``project_ball`` / ``norm_reduce`` / ``project_grouped``) instead of being
copy-pasted ``if q in (...)`` chains across modules.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Dict, NamedTuple, Sequence, Union

import jax
import jax.numpy as jnp

Scalar = Union[float, jax.Array]

_BISECT_ITERS = 64  # enough for float32 exactness on well-scaled data


def _soft_threshold(a: jax.Array, theta: jax.Array) -> jax.Array:
    return jnp.maximum(a - theta, 0.0)


# --------------------------------------------------------------------------- #
# θ solvers: sum(max(a - θ, 0)) == radius for non-negative a.
#
# Ball contract  : return θ <= 0 when sum(a) <= radius (projection = identity).
# Simplex contract: always solve the equality (θ may be negative).
# --------------------------------------------------------------------------- #


def simplex_threshold_sort(a: jax.Array, radius: Scalar) -> jax.Array:
    """Threshold θ s.t. sum(max(a - θ, 0)) == radius, for non-negative ``a``.

    Sort-based exact evaluation over the last axis. Returns θ with the same
    leading (batch) shape as ``a`` minus the last axis. If ``sum(a) <= radius``
    the returned θ is <= 0 so that soft-thresholding is the identity on a >= 0.
    """
    radius = jnp.asarray(radius, a.dtype)
    r = radius[..., None]  # broadcast over the reduced axis (works for 0-d too)
    a_sorted = jnp.sort(a, axis=-1)[..., ::-1]  # descending
    csum = jnp.cumsum(a_sorted, axis=-1)
    n = a.shape[-1]
    ks = jnp.arange(1, n + 1, dtype=a.dtype)
    # candidate thresholds if exactly k entries stay positive
    thetas = (csum - r) / ks
    # k is valid while a_sorted[k-1] > theta_k ; pick the largest valid k
    valid = a_sorted > thetas
    k = jnp.sum(valid, axis=-1)  # >= 1 when sum(a) > radius (radius > 0)
    k = jnp.maximum(k, 1)
    theta = jnp.take_along_axis(thetas, k[..., None] - 1, axis=-1)[..., 0]
    # already feasible -> no shrink
    inside = csum[..., -1] <= radius
    return jnp.where(inside, jnp.zeros_like(theta) - 1.0, theta)


def simplex_threshold_bisect(
    a: jax.Array, radius: Scalar, iters: int = _BISECT_ITERS
) -> jax.Array:
    """Bisection evaluation of the simplex threshold (fully data-parallel).

    φ(θ) = sum(max(a-θ,0)) is continuous, strictly decreasing on [0, max(a)]
    wherever positive; we bisect φ(θ) = radius. Matches the sort variant to
    ~machine precision after 64 iterations.
    """
    radius = jnp.asarray(radius, a.dtype)
    hi = jnp.max(a, axis=-1)
    lo = jnp.zeros_like(hi)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(_soft_threshold(a, mid[..., None]), axis=-1)
        too_small = phi > radius  # θ too small -> raise lo
        lo = jnp.where(too_small, mid, lo)
        hi = jnp.where(too_small, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    inside = jnp.sum(a, axis=-1) <= radius
    return jnp.where(inside, jnp.full_like(theta, -1.0), theta)


def _filter_theta(a: jax.Array, radius: jax.Array) -> jax.Array:
    """Michelot fixed-point θ for the *equality* constraint, batched.

    θ₀ = (Σa - r)/n; repeat θ ← (Σ_{aᵢ>θ} aᵢ - r)/#{aᵢ>θ} until the active set
    stops shrinking. θ is non-decreasing and the active set is monotone, so the
    loop terminates in at most n sweeps (a handful on typical data — expected
    O(n) total work, Michelot 1986 / Condat 2016). Rows that have converged are
    at a fixed point, so the batched loop runs until ALL rows converge without
    disturbing finished ones.
    """
    n = a.shape[-1]
    s0 = jnp.sum(a, axis=-1)
    r = jnp.broadcast_to(jnp.asarray(radius, a.dtype), s0.shape)
    theta0 = (s0 - r) / n
    count0 = jnp.full(s0.shape, n, dtype=jnp.int32)
    done0 = jnp.zeros(s0.shape, dtype=bool)

    def cond(state):
        _, _, done, it = state
        return jnp.logical_and(jnp.logical_not(jnp.all(done)), it < n + 2)

    def body(state):
        theta, count, done, it = state
        active = a > theta[..., None]
        new_count = jnp.sum(active, axis=-1, dtype=jnp.int32)
        ssum = jnp.sum(jnp.where(active, a, 0.0), axis=-1)
        new_theta = (ssum - r) / jnp.maximum(new_count, 1).astype(a.dtype)
        # empty active set (radius ~0 edge): current θ already clips everything
        new_theta = jnp.where(new_count > 0, new_theta, theta)
        converged = (new_count == count) | (new_count == 0)
        theta = jnp.where(done, theta, new_theta)
        count = jnp.where(done, count, new_count)
        return theta, count, done | converged, it + 1

    theta, _, _, _ = jax.lax.while_loop(cond, body, (theta0, count0, done0, 0))
    return theta


def _filter_theta_diff(a: jax.Array, radius: jax.Array) -> jax.Array:
    """``_filter_theta`` made reverse-mode differentiable.

    The ``while_loop`` (not transposable) runs entirely on stopped gradients —
    it only has to FIND the active set. θ is then recomputed from that set as
    a closed-form expression of ``(a, radius)``: θ = (Σ_{active} aᵢ - r)/#active.
    The active set is locally constant in ``a``, so autodiff through the
    recomputation yields the exact projection Jacobian (the same one the
    ``sort`` backend's differentiable graph produces).
    """
    theta0 = _filter_theta(jax.lax.stop_gradient(a),
                           jax.lax.stop_gradient(radius))
    active = jax.lax.stop_gradient(a > theta0[..., None])
    count = jnp.sum(active, axis=-1)
    ssum = jnp.sum(jnp.where(active, a, 0.0), axis=-1)
    r = jnp.broadcast_to(jnp.asarray(radius, a.dtype), ssum.shape)
    theta = (ssum - r) / jnp.maximum(count, 1).astype(a.dtype)
    # empty active set (radius ~ 0 edge): keep the loop's θ, it clips everything
    return jnp.where(count > 0, theta, theta0)


def simplex_threshold_filter(a: jax.Array, radius: Scalar) -> jax.Array:
    """Michelot/Condat filtering θ (ball contract: θ = -1 when inside)."""
    radius = jnp.asarray(radius, a.dtype)
    theta = _filter_theta_diff(a, radius)
    inside = jnp.sum(a, axis=-1) <= radius
    return jnp.where(inside, jnp.full_like(theta, -1.0), theta)


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #


class L1Method(NamedTuple):
    """One ℓ1/simplex θ-solver backend.

    ``ball_theta``    — θ with the ball contract (θ <= 0 ⇒ identity inside).
    ``simplex_theta`` — θ for the equality constraint (may be negative).
    ``complexity``    — human-readable work bound (docs/benchmarks).
    ``differentiable``— safe under reverse-mode autodiff.
    """

    ball_theta: Callable[[jax.Array, Scalar], jax.Array]
    simplex_theta: Callable[[jax.Array, Scalar], jax.Array]
    complexity: str
    differentiable: bool


def _simplex_theta_sort(a: jax.Array, radius: Scalar) -> jax.Array:
    a_sorted = jnp.sort(a, axis=-1)[..., ::-1]
    csum = jnp.cumsum(a_sorted, axis=-1)
    n = a.shape[-1]
    ks = jnp.arange(1, n + 1, dtype=a.dtype)
    thetas = (csum - jnp.asarray(radius, a.dtype)[..., None]) / ks
    valid = a_sorted > thetas
    k = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    return jnp.take_along_axis(thetas, k[..., None] - 1, axis=-1)[..., 0]


def _simplex_theta_bisect(a: jax.Array, radius: Scalar) -> jax.Array:
    # bisection over [min(a)-radius/n, max(a)] (θ may be negative)
    radius = jnp.asarray(radius, a.dtype)
    hi = jnp.max(a, axis=-1)
    lo = jnp.min(a, axis=-1) - radius / a.shape[-1]

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - mid[..., None], 0.0), axis=-1)
        too_small = phi > radius
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
    return 0.5 * (lo + hi)


def _simplex_theta_filter(a: jax.Array, radius: Scalar) -> jax.Array:
    return _filter_theta_diff(a, jnp.asarray(radius, a.dtype))


_L1_METHODS: Dict[str, L1Method] = {}
_L1_ALIASES: Dict[str, str] = {}

DEFAULT_METHOD = "sort"


def register_l1_method(name: str, method: L1Method, *,
                       aliases: Sequence[str] = ()) -> None:
    """Register an ℓ1 θ-solver backend. One call makes it available everywhere
    a ``method=`` kwarg exists (core, kernels dispatch, optim hook, benches)."""
    _L1_METHODS[name] = method
    for alias in aliases:
        _L1_ALIASES[alias] = name


def resolve_method(method: str | None, *, default: str = DEFAULT_METHOD) -> str:
    """Canonicalize a backend name (None → default, aliases → canonical).

    Raises ``ValueError`` for unknown names — the single place config errors
    about projection backends surface.
    """
    if method is None:
        method = default
    name = _L1_ALIASES.get(method, method)
    if name not in _L1_METHODS:
        raise ValueError(
            f"unknown l1 method {method!r}; available: {sorted(_L1_METHODS)}"
        )
    return name


def available_methods() -> tuple:
    """Canonical names of all registered ℓ1 backends."""
    return tuple(sorted(_L1_METHODS))


def method_info(method: str) -> L1Method:
    """Registry record for a (possibly aliased) backend name."""
    return _L1_METHODS[resolve_method(method)]


register_l1_method("sort", L1Method(
    simplex_threshold_sort, _simplex_theta_sort,
    complexity="O(n log n)", differentiable=True))
register_l1_method("bisect", L1Method(
    simplex_threshold_bisect, _simplex_theta_bisect,
    complexity="O(k n), k=64 fixed", differentiable=True))
register_l1_method("filter", L1Method(
    simplex_threshold_filter, _simplex_theta_filter,
    complexity="O(n) expected", differentiable=True),
    aliases=("michelot", "condat"))


# --------------------------------------------------------------------------- #
# Projections
# --------------------------------------------------------------------------- #


def project_simplex(y: jax.Array, radius: Scalar = 1.0, method: str = "sort") -> jax.Array:
    """Euclidean projection onto {x >= 0, sum(x) == radius} over the last axis."""
    # equality constraint: always apply the threshold, even inside the l1 ball.
    theta = _L1_METHODS[resolve_method(method)].simplex_theta(y, radius)
    return jnp.maximum(y - theta[..., None], 0.0)


def project_l1(y: jax.Array, radius: Scalar, method: str = "sort") -> jax.Array:
    """Euclidean projection onto the ℓ1 ball of ``radius`` over the last axis."""
    a = jnp.abs(y)
    theta = _L1_METHODS[resolve_method(method)].ball_theta(a, radius)
    return jnp.sign(y) * _soft_threshold(a, jnp.maximum(theta, 0.0)[..., None])


# convenience aliases used by kernels/ref and benchmarks
project_l1_sort = functools.partial(project_l1, method="sort")
project_l1_bisect = functools.partial(project_l1, method="bisect")
project_l1_filter = functools.partial(project_l1, method="filter")


def project_l2(y: jax.Array, radius: Scalar) -> jax.Array:
    """Projection onto the ℓ2 ball over the last axis: pure rescale."""
    radius = jnp.asarray(radius, y.dtype)
    nrm = jnp.linalg.norm(y, axis=-1, keepdims=True)
    scale = jnp.where(nrm > radius[..., None], radius[..., None] / jnp.maximum(nrm, 1e-30), 1.0)
    return y * scale


def project_linf(y: jax.Array, radius: Scalar) -> jax.Array:
    """Projection onto the ℓ∞ ball: elementwise clip. ``radius`` broadcasts."""
    radius = jnp.asarray(radius, y.dtype)
    if radius.ndim:
        radius = radius[..., None]
    return jnp.clip(y, -radius, radius)


# --------------------------------------------------------------------------- #
# Per-norm dispatch tables
# --------------------------------------------------------------------------- #

_NORM_NAMES = {1: "1", "1": "1", 2: "2", "2": "2",
               jnp.inf: "inf", float("inf"): "inf", "inf": "inf"}


def canonical_norm(norm) -> str:
    """Canonical name ('1' | '2' | 'inf') of a norm spec, or ValueError."""
    try:
        return _NORM_NAMES[norm]
    except (KeyError, TypeError):
        raise ValueError(f"unsupported norm {norm!r}") from None


def project_ball(y: jax.Array, norm, radius: Scalar, method: str = "sort") -> jax.Array:
    """Dispatch: project the last axis of ``y`` onto the ``norm``-ball.

    ``norm`` ∈ {1, 2, jnp.inf, 'inf'}.
    """
    q = canonical_norm(norm)
    if q == "1":
        return project_l1(y, radius, method=method)
    if q == "2":
        return project_l2(y, radius)
    return project_linf(y, radius)


def norm_reduce(y: jax.Array, norm, axes) -> jax.Array:
    """Aggregate ``y`` over ``axes`` with the given norm (the v_q of the paper)."""
    q = canonical_norm(norm)
    if q == "1":
        return jnp.sum(jnp.abs(y), axis=axes)
    if q == "2":
        return jnp.sqrt(jnp.sum(jnp.square(y), axis=axes))
    return jnp.max(jnp.abs(y), axis=axes)


def project_grouped(y: jax.Array, norm, radii: jax.Array, inner_axes,
                    method: str = "sort") -> jax.Array:
    """Project every group of ``y`` onto its own ``norm``-ball.

    A group is a slice over ``inner_axes``; ``radii`` has the shape of the
    remaining (outer) axes. This is the shared inner step of the bi-/multi-level
    projections — the single home of the per-norm group dispatch that used to be
    copy-pasted across bilevel.py / multilevel.py / sharded.py.
    """
    inner_axes = tuple(a % y.ndim for a in inner_axes)
    outer_axes = tuple(a for a in range(y.ndim) if a not in inner_axes)
    q = canonical_norm(norm)
    u_b = jnp.expand_dims(radii, inner_axes)  # broadcast radii over the groups
    if q == "inf":
        return jnp.clip(y, -u_b, u_b)
    if q == "2":
        nrm = jnp.sqrt(jnp.sum(jnp.square(y), axis=inner_axes, keepdims=True))
        scale = jnp.where(nrm > u_b, u_b / jnp.maximum(nrm, 1e-30), 1.0)
        return y * scale
    # q == "1": move the group axes last, flatten, batched l1 projection
    perm = outer_axes + inner_axes
    yt = jnp.transpose(y, perm)
    outer_shape = yt.shape[: len(outer_axes)]
    inner_size = math.prod(yt.shape[len(outer_axes):])
    proj = project_l1(yt.reshape((-1, inner_size)), radii.reshape(-1), method=method)
    proj = proj.reshape(outer_shape + yt.shape[len(outer_axes):])
    inv = tuple(perm.index(i) for i in range(y.ndim))
    return jnp.transpose(proj, inv)


def ball_norm(x: jax.Array, norm, axis=-1) -> jax.Array:
    """Vector norm along ``axis`` (thin wrapper used by tests/invariants)."""
    return norm_reduce(x, norm, axis)
