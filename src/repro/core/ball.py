"""Vector norm-ball projections — the primitives every level of the multi-level
projection is built from.

All functions are pure JAX (jit/vmap/grad-safe), operate on the *last* axis of the
input unless stated otherwise, and accept a scalar or broadcastable ``radius``.

Two ℓ1 algorithms are provided (see DESIGN.md §3 — hardware adaptation):

* ``project_l1_sort``  — sort + prefix-sum threshold (Duchi et al. / Held et al.).
  O(n log n) work, O(log n) depth. Exact.
* ``project_l1_bisect`` — bisection on the soft-threshold θ. O(k·n) work with k fixed
  iterations, O(k log n) depth, only elementwise ops + reductions: the TPU/Pallas
  friendly variant. Accurate to ~2^-k of the value range.

Both reduce to the simplex projection of |y| followed by sign restoration.
"""

from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp

Scalar = Union[float, jax.Array]

_BISECT_ITERS = 64  # enough for float32 exactness on well-scaled data


def _soft_threshold(a: jax.Array, theta: jax.Array) -> jax.Array:
    return jnp.maximum(a - theta, 0.0)


def simplex_threshold_sort(a: jax.Array, radius: Scalar) -> jax.Array:
    """Threshold θ s.t. sum(max(a - θ, 0)) == radius, for non-negative ``a``.

    Sort-based exact evaluation over the last axis. Returns θ with the same
    leading (batch) shape as ``a`` minus the last axis. If ``sum(a) <= radius``
    the returned θ is <= 0 so that soft-thresholding is the identity on a >= 0.
    """
    radius = jnp.asarray(radius, a.dtype)
    r = radius[..., None]  # broadcast over the reduced axis (works for 0-d too)
    a_sorted = jnp.sort(a, axis=-1)[..., ::-1]  # descending
    csum = jnp.cumsum(a_sorted, axis=-1)
    n = a.shape[-1]
    ks = jnp.arange(1, n + 1, dtype=a.dtype)
    # candidate thresholds if exactly k entries stay positive
    thetas = (csum - r) / ks
    # k is valid while a_sorted[k-1] > theta_k ; pick the largest valid k
    valid = a_sorted > thetas
    k = jnp.sum(valid, axis=-1)  # >= 1 when sum(a) > radius (radius > 0)
    k = jnp.maximum(k, 1)
    theta = jnp.take_along_axis(thetas, k[..., None] - 1, axis=-1)[..., 0]
    # already feasible -> no shrink
    inside = csum[..., -1] <= radius
    return jnp.where(inside, jnp.zeros_like(theta) - 1.0, theta)


def simplex_threshold_bisect(
    a: jax.Array, radius: Scalar, iters: int = _BISECT_ITERS
) -> jax.Array:
    """Bisection evaluation of the simplex threshold (fully data-parallel).

    φ(θ) = sum(max(a-θ,0)) is continuous, strictly decreasing on [0, max(a)]
    wherever positive; we bisect φ(θ) = radius. Matches the sort variant to
    ~machine precision after 64 iterations.
    """
    radius = jnp.asarray(radius, a.dtype)
    hi = jnp.max(a, axis=-1)
    lo = jnp.zeros_like(hi)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(_soft_threshold(a, mid[..., None]), axis=-1)
        too_small = phi > radius  # θ too small -> raise lo
        lo = jnp.where(too_small, mid, lo)
        hi = jnp.where(too_small, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    inside = jnp.sum(a, axis=-1) <= radius
    return jnp.where(inside, jnp.full_like(theta, -1.0), theta)


def project_simplex(y: jax.Array, radius: Scalar = 1.0, method: str = "sort") -> jax.Array:
    """Euclidean projection onto {x >= 0, sum(x) == radius} over the last axis."""
    # equality constraint: always apply the threshold, even inside the l1 ball.
    theta = _simplex_theta_always(y, radius, method)
    return jnp.maximum(y - theta[..., None], 0.0)


def _simplex_theta_always(a: jax.Array, radius: Scalar, method: str) -> jax.Array:
    """Simplex θ without the 'inside the ball' shortcut (equality constraint)."""
    if method == "sort":
        a_sorted = jnp.sort(a, axis=-1)[..., ::-1]
        csum = jnp.cumsum(a_sorted, axis=-1)
        n = a.shape[-1]
        ks = jnp.arange(1, n + 1, dtype=a.dtype)
        thetas = (csum - jnp.asarray(radius, a.dtype)[..., None]) / ks
        valid = a_sorted > thetas
        k = jnp.maximum(jnp.sum(valid, axis=-1), 1)
        return jnp.take_along_axis(thetas, k[..., None] - 1, axis=-1)[..., 0]
    # bisection over [min(a)-radius/n, max(a)]
    radius = jnp.asarray(radius, a.dtype)
    hi = jnp.max(a, axis=-1)
    lo = jnp.min(a, axis=-1) - radius / a.shape[-1]

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(a - mid[..., None], 0.0), axis=-1)
        too_small = phi > radius
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
    return 0.5 * (lo + hi)


def project_l1(y: jax.Array, radius: Scalar, method: str = "sort") -> jax.Array:
    """Euclidean projection onto the ℓ1 ball of ``radius`` over the last axis."""
    a = jnp.abs(y)
    if method == "sort":
        theta = simplex_threshold_sort(a, radius)
    elif method == "bisect":
        theta = simplex_threshold_bisect(a, radius)
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown l1 method {method!r}")
    return jnp.sign(y) * _soft_threshold(a, jnp.maximum(theta, 0.0)[..., None])


# convenience aliases used by kernels/ref and benchmarks
project_l1_sort = functools.partial(project_l1, method="sort")
project_l1_bisect = functools.partial(project_l1, method="bisect")


def project_l2(y: jax.Array, radius: Scalar) -> jax.Array:
    """Projection onto the ℓ2 ball over the last axis: pure rescale."""
    radius = jnp.asarray(radius, y.dtype)
    nrm = jnp.linalg.norm(y, axis=-1, keepdims=True)
    scale = jnp.where(nrm > radius[..., None], radius[..., None] / jnp.maximum(nrm, 1e-30), 1.0)
    return y * scale


def project_linf(y: jax.Array, radius: Scalar) -> jax.Array:
    """Projection onto the ℓ∞ ball: elementwise clip. ``radius`` broadcasts."""
    radius = jnp.asarray(radius, y.dtype)
    if radius.ndim:
        radius = radius[..., None]
    return jnp.clip(y, -radius, radius)


def project_ball(y: jax.Array, norm, radius: Scalar, method: str = "sort") -> jax.Array:
    """Dispatch: project the last axis of ``y`` onto the ``norm``-ball.

    ``norm`` ∈ {1, 2, jnp.inf, 'inf'}.
    """
    if norm in (1, "1"):
        return project_l1(y, radius, method=method)
    if norm in (2, "2"):
        return project_l2(y, radius)
    if norm in (jnp.inf, float("inf"), "inf"):
        return project_linf(y, radius)
    raise ValueError(f"unsupported norm {norm!r}")


def norm_reduce(y: jax.Array, norm, axes) -> jax.Array:
    """Aggregate ``y`` over ``axes`` with the given norm (the v_q of the paper)."""
    if norm in (1, "1"):
        return jnp.sum(jnp.abs(y), axis=axes)
    if norm in (2, "2"):
        return jnp.sqrt(jnp.sum(jnp.square(y), axis=axes))
    if norm in (jnp.inf, float("inf"), "inf"):
        return jnp.max(jnp.abs(y), axis=axes)
    raise ValueError(f"unsupported norm {norm!r}")


def ball_norm(x: jax.Array, norm, axis=-1) -> jax.Array:
    """Vector norm along ``axis`` (thin wrapper used by tests/invariants)."""
    return norm_reduce(x, norm, axis)
