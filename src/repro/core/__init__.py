"""repro.core — the paper's contribution: bi-/multi-level norm-ball projections."""

from .ball import (  # noqa: F401
    available_methods,
    ball_norm,
    canonical_norm,
    method_info,
    norm_reduce,
    project_ball,
    project_grouped,
    project_l1,
    project_l1_bisect,
    project_l1_filter,
    project_l1_sort,
    project_l2,
    project_linf,
    project_simplex,
    register_l1_method,
    resolve_method,
)
from .bilevel import (  # noqa: F401
    bilevel_l11,
    bilevel_l12,
    bilevel_l1inf,
    bilevel_l21,
    bilevel_project,
    bilevel_project_axes,
)
from .exact_l1inf import (  # noqa: F401
    l1inf_norm,
    project_l1inf_exact,
    project_l1inf_exact_bisect,
)
from .masks import apply_mask, column_mask, element_sparsity, mask_tree, sparsity  # noqa: F401
from .plan import (  # noqa: F401
    PlanBackend,
    ProjectionPlan,
    best_l1_method,
    make_plan,
    register_plan_backend,
)
from .multilevel import (  # noqa: F401
    multilevel_norm,
    multilevel_project,
    trilevel_l111,
    trilevel_l1infinf,
    work_depth,
)
from .schedule import (  # noqa: F401
    ApplyGroup,
    OuterSolve,
    ReduceLevel,
    Schedule,
    compile_schedule,
)
from .sharded import (  # noqa: F401
    bilevel_project_sharded,
    make_schedule_body,
    make_sharded_bilevel,
    make_sharded_trilevel,
    multilevel_project_sharded,
    sharded_collective_bytes,
    trilevel_project_sharded,
)
