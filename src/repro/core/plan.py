"""Projection planner/autotuner — pick, compile, and cache the fastest
executable for a projection workload (DESIGN.md §2).

``multilevel_project`` is correct for every (shape, ν, backend) combination,
but per-call dispatch re-resolves the method and re-traces on every new
enclosing jit. The planner hoists all of that to *build time*:

    build    — validate the norm design against the shape ONCE
    autotune — ``method="auto"``: micro-benchmark every available backend on
               synthetic data of the exact (shape, dtype) and keep the winner
    cache    — the winner AND the jitted executable are memoised keyed on
               ``(shape, dtype, levels, radius_kind, device)``; a second
               ``make_plan`` (or a second call of the plan) never re-traces
    execute  — ``plan(y, radius)`` runs the reused jitted executable

Backends are (a) every ℓ1 θ-solver in the ``core.ball`` registry, applied
through ``multilevel_project``, and (b) *specialized* executables registered
via ``register_plan_backend`` — the ``codegen`` generated fused kernels
(``repro.kernels.plan_backends`` / ``repro.kernels.codegen``: any unsharded
norm design the tiler accepts), offered on TPU (or under ``interpret=True``
for tests), and the ``sharded`` schedule executor for mesh-committed keys.

Example (fixed backend; ``method="auto"`` benchmarks first):

>>> import jax.numpy as jnp
>>> from repro.core import plan
>>> p = plan.make_plan((4, 8), "float32", [("inf", 1), ("1", 1)],
...                    method="filter")
>>> p.method
'filter'
>>> X = p(jnp.ones((4, 8)), 2.0)
>>> float(jnp.sum(jnp.max(jnp.abs(X), axis=0)))   # inside the l1,inf ball
2.0
>>> plan.make_plan((4, 8), "float32", [("inf", 1), ("1", 1)],
...                method="filter") is p           # plan cache hit
True
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ball, multilevel, schedule

AUTO = "auto"

_AUTOTUNE_BATCH = 4     # representative batch size for radius_kind="batch"
_AUTOTUNE_REPS = 7      # interleaved timing rounds (min per candidate kept)

_RADIUS_KINDS = ("scalar", "batch")


class ShardingKey(NamedTuple):
    """Canonical, hashable description of a mesh sharding (PlanKey component).

    ``mesh_axes`` is ``((axis_name, size), ...)`` in mesh order; ``devices``
    the flat device-id assignment (two meshes with equal axis signatures but
    different device sets/orders must not alias one plan); ``spec`` maps each
    tensor axis to a mesh axis name (or None). The live Mesh object is kept
    in a side registry keyed on ``(mesh_axes, devices)`` — registered
    whenever a plan is built from a real mesh, looked up when the sharded
    backend builds.
    """

    mesh_axes: Tuple[Tuple[str, int], ...]
    devices: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]


class PlanKey(NamedTuple):
    """The cache key a plan is specialized on."""

    shape: Tuple[int, ...]
    dtype: str
    levels: Tuple[Tuple[str, int], ...]   # canonical ('1'|'2'|'inf', n_axes)
    radius_kind: str                      # 'scalar' | 'batch'
    device: str                           # jax platform ('cpu' | 'tpu' | ...)
    interpret: bool = False               # Pallas interpret mode (tests)
    sharding: Optional[ShardingKey] = None  # None = single-device workload
    grad: bool = False                    # training key: autotuned under vjp


class PlanBackend(NamedTuple):
    """A specialized planner backend (e.g. a fused Pallas kernel).

    ``available(key)`` gates shape/levels/device eligibility; ``build(key)``
    returns the raw ``(y, radius) -> x`` callable (the planner jits it).
    ``batch_native=True`` marks a backend whose built callable already takes
    the stacked ``(ys, radii)`` serving-bucket shape (the batch axis lives in
    its Pallas grid): the planner jits it as-is for ``radius_kind="batch"``
    keys instead of vmap-lifting a per-item callable, and never offers it for
    scalar-radius keys.
    """

    name: str
    available: Callable[[PlanKey], bool]
    build: Callable[[PlanKey], Callable]
    description: str = ""
    batch_native: bool = False


class _Executable(NamedTuple):
    fn: Callable        # jitted (y, radius) -> x
    traces: List[int]   # [trace count] — bumped by the traced body


_SPECIALIZED: Dict[str, PlanBackend] = {}
_EXECS: Dict[Tuple[PlanKey, str], _Executable] = {}
_PLANS: Dict[Tuple[PlanKey, str], "ProjectionPlan"] = {}
_AUTO_WINNERS: Dict[PlanKey, Tuple[str, Dict[str, float]]] = {}
_MESHES: Dict[Tuple[Tuple[str, int], ...], object] = {}  # ShardingKey.mesh_axes -> Mesh
_KERNEL_BACKENDS_LOADED = False

# cache-lifecycle counters (observability): hits/misses describe the current
# cache generation (reset together with the caches by clear_cache, so sizes
# and counters always refer to the same lifetime); "evictions" is cumulative
# over the process (Prometheus counter semantics — a clear IS an eviction
# event, so it must survive the clear that caused it)
_COUNTER_KEYS = ("plan_hits", "plan_misses", "exec_hits", "exec_misses",
                 "retraces", "autotune_runs", "autotune_hits")
_COUNTERS: Dict[str, int] = dict.fromkeys(_COUNTER_KEYS, 0)
_EVICTIONS = [0]


def _count(event: str, n: int = 1) -> None:
    _COUNTERS[event] += n


def register_plan_backend(backend: PlanBackend) -> None:
    """Register (or replace) a specialized planner backend by name."""
    _SPECIALIZED[backend.name] = backend


def clear_cache() -> None:
    """Drop every cached plan, executable, and autotune verdict
    (tests/benches), and reset the generation counters with them.

    Dropped entries count into the cumulative ``evictions`` counter; the
    hit/miss/retrace/autotune counters restart at zero so ``cache_info()``
    sizes and counters always describe the same cache generation.
    """
    _EVICTIONS[0] += len(_PLANS) + len(_EXECS) + len(_AUTO_WINNERS)
    _EXECS.clear()
    _PLANS.clear()
    _AUTO_WINNERS.clear()
    _COUNTERS.update(dict.fromkeys(_COUNTER_KEYS, 0))


def cache_info() -> Dict[str, int]:
    """Sizes AND lifecycle counters of the planner caches.

    Sizes: ``plans`` / ``executables`` / ``auto_winners``. Counters (since
    the last :func:`clear_cache`): ``plan_hits``/``plan_misses`` (the
    ``make_plan`` memo), ``exec_hits``/``exec_misses`` (jitted executables),
    ``retraces`` (executable body re-traces beyond the first — a nonzero
    value means some call pattern defeats the jit cache),
    ``autotune_runs``/``autotune_hits`` (micro-benchmark shoot-outs vs
    cached verdicts). ``evictions`` is cumulative over the process. The
    same numbers are mirrored into the obs registry as
    ``plan_cache_<name>`` gauges on every call.
    """
    info = {"plans": len(_PLANS), "executables": len(_EXECS),
            "auto_winners": len(_AUTO_WINNERS), **_COUNTERS,
            "evictions": _EVICTIONS[0]}
    try:
        from repro.obs import metrics as _obs_metrics

        gauge = _obs_metrics.get_registry().gauge(
            "plan_cache", "planner cache sizes and lifecycle counters "
            "(core.plan.cache_info)", labels=("stat",))
        for name, v in info.items():
            gauge.labels(stat=name).set(v)
    except Exception:  # pragma: no cover - obs must never break the planner
        pass
    return info


# the single home of norm-design canonicalization is the schedule IR;
# re-exported here because every planner consumer keys on it
canonical_levels = schedule.canonical_levels


def canonical_sharding(sharding, ndim: int) -> Optional[ShardingKey]:
    """Fold a sharding description into a hashable :class:`ShardingKey`.

    Accepts ``None``, an already-canonical ``ShardingKey``, a committed
    ``jax.sharding.NamedSharding``, or a ``(mesh, partition_spec)`` pair.
    Returns ``None`` for shardings the mesh executor does not handle (fully
    replicated, single-device, or >1 mesh axis on one tensor axis) — those
    route to the ordinary single-device backends. Registers the live mesh in
    the side registry so the sharded backend can rebuild from the key alone.
    """
    if sharding is None or isinstance(sharding, ShardingKey):
        return sharding
    if isinstance(sharding, jax.sharding.NamedSharding):
        mesh, spec = sharding.mesh, sharding.spec
    else:
        mesh, spec = sharding
    if np.prod(list(mesh.shape.values())) <= 1:
        return None
    from . import sharded as shmod

    names = shmod.parse_spec(spec, ndim, mesh)  # the one spec parser
    if names is None:
        return None  # >1 mesh axis on a tensor axis: executor can't run it
    if not any(names):
        return None  # fully replicated: a plain single-device workload
    mesh_axes = tuple((str(n), int(s)) for n, s in mesh.shape.items())
    devices = tuple(int(d.id) for d in mesh.devices.flat)
    _MESHES[mesh_axes, devices] = mesh
    return ShardingKey(mesh_axes, devices, tuple(names))


def _sharded_available(key: PlanKey) -> bool:
    # scalar-radius only: a batch plan vmaps its executable, and shard_map
    # bodies don't batch — sharded serving groups run per-request instead.
    # Training (grad) keys are excluded too: differentiating through the
    # shard_map body is untested; mesh-native training keeps the hook path.
    return (key.sharding is not None and key.radius_kind == "scalar"
            and not key.grad
            and (key.sharding.mesh_axes, key.sharding.devices) in _MESHES)


def _build_sharded(key: PlanKey):
    from . import sharded as shmod

    mesh = _MESHES[key.sharding.mesh_axes, key.sharding.devices]
    spec = key.sharding.spec
    levels = list(key.levels)

    def fn(y, radius):
        return shmod.multilevel_project_sharded(
            y, levels, radius, mesh=mesh, spec=spec, method="auto")

    return fn


register_plan_backend(PlanBackend(
    name="sharded",
    available=_sharded_available,
    build=_build_sharded,
    description="schedule executor under shard_map: collective reduces, "
                "gathered tiny outer solve, local applies (DESIGN.md §3)",
))


_L1INF_LEVELS = (("inf", 1), ("1", 1))


def _exact_l1inf_available(key: PlanKey) -> bool:
    # The EXACT ℓ1,∞ projection (Chu et al. semismooth Newton) targets the
    # same ball as the bi-level design but is a different operator — offering
    # it under method="auto" deliberately trades bi-level's O(1/n) looseness
    # for measured speed (the equality matrix pins it at loose tolerance).
    # Unsharded 2-D scalar-radius forward keys only: the Newton fori_loop and
    # the per-column sort make its vjp cost pathological for training keys.
    return (key.levels == _L1INF_LEVELS and len(key.shape) == 2
            and key.sharding is None and key.radius_kind == "scalar"
            and not key.grad)


def _build_exact_l1inf(key: PlanKey):
    from .exact_l1inf import project_l1inf_exact

    def fn(y, radius):
        return project_l1inf_exact(y, radius)

    return fn


register_plan_backend(PlanBackend(
    name="exact_l1inf",
    available=_exact_l1inf_available,
    build=_build_exact_l1inf,
    description="EXACT l1,inf projection (Chu et al. semismooth Newton on "
                "the dual): same ball as the bi-level design, exact optimum "
                "— method='auto' trades exactness for speed by measurement",
))


def _maybe_register_kernel_backends() -> None:
    """Lazily pull in the fused-kernel backends (kernels imports core, so core
    cannot import kernels at module load — first make_plan does it instead)."""
    global _KERNEL_BACKENDS_LOADED
    if _KERNEL_BACKENDS_LOADED:
        return
    _KERNEL_BACKENDS_LOADED = True
    try:
        from repro.kernels import plan_backends  # noqa: F401  (registers on import)
    except Exception:  # pragma: no cover - jax without pallas support
        pass


def _backend_available(backend: PlanBackend, key: PlanKey) -> bool:
    """Availability incl. the batch-native gate (batch-native backends take
    the stacked bucket shape, so they only fit ``radius_kind="batch"`` keys)."""
    if backend.batch_native and key.radius_kind != "batch":
        return False
    return backend.available(key)


def is_batch_native(name: str) -> bool:
    """True when ``name`` is a registered batch-native specialized backend
    (its executables take stacked ``(ys, radii)`` buckets only — a serving
    group routed to it must dispatch through a batch plan even for size 1)."""
    backend = _SPECIALIZED.get(name)
    return backend is not None and backend.batch_native


def _build_backend_fn(key: PlanKey, name: str) -> Callable:
    """Raw (y, radius) -> x callable for one backend on one key."""
    if name in _SPECIALIZED:
        backend = _SPECIALIZED[name]
        if not _backend_available(backend, key):
            raise ValueError(
                f"backend {name!r} is not available for plan key {key}")
        return backend.build(key)
    method = ball.resolve_method(name)
    levels = list(key.levels)

    def fn(y, radius):
        return multilevel.multilevel_project(y, levels, radius, method=method)

    return fn


def _get_executable(key: PlanKey, name: str, donate: bool = False) -> _Executable:
    ek = (key, name, donate)
    if ek in _EXECS:
        _count("exec_hits")
        return _EXECS[ek]
    _count("exec_misses")
    base = _build_backend_fn(key, name)
    traces = [0]

    def counted(y, radius):
        traces[0] += 1  # python side effect: runs at trace time only
        if traces[0] > 1:
            _count("retraces")
        return base(y, radius)

    # a batch-native backend already takes the stacked (ys, radii) bucket —
    # jit it as-is; everything else vmap-lifts the per-item callable
    if key.radius_kind == "batch" and not is_batch_native(name):
        body = jax.vmap(counted, in_axes=(0, 0))
    else:
        body = counted
    # donate=True consumes the payload buffer in place (serving: the request
    # tensor — or the stacked bucket — is dead after projection anyway)
    fn = jax.jit(body, donate_argnums=(0,) if donate else ())
    ex = _Executable(fn, traces)
    _EXECS[ek] = ex
    return ex


def _candidates(key: PlanKey) -> List[str]:
    """Backends worth benchmarking for this key.

    For a sharded key the generic θ-solvers still compete: jitted on the
    committed sharded input they become the GSPMD gather-and-project
    baseline, so autotune decides schedule-vs-gather by measurement."""
    if any(q == "1" for q, _ in key.levels):
        names = list(ball.available_methods())
    else:
        # no l1 level anywhere -> the θ-solver is never invoked; one generic
        # executable is enough
        names = [ball.DEFAULT_METHOD]
    names += [b.name for b in _SPECIALIZED.values()
              if _backend_available(b, key)]
    return names


def _bench_args(key: PlanKey):
    rng = np.random.default_rng(0)
    shape = key.shape if key.radius_kind == "scalar" \
        else (_AUTOTUNE_BATCH,) + key.shape
    y = jnp.asarray(rng.uniform(0.0, 1.0, shape), key.dtype)
    if key.sharding is not None:
        mesh = _MESHES[key.sharding.mesh_axes, key.sharding.devices]
        spec = key.sharding.spec
        if key.radius_kind == "batch":
            spec = (None,) + spec
        y = jax.device_put(y, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*spec)))
    if key.radius_kind == "scalar":
        radius = jnp.asarray(1.0, key.dtype)
    else:
        radius = jnp.ones((_AUTOTUNE_BATCH,), key.dtype)
    return y, radius


def _grad_fn(key: PlanKey, name: str) -> Callable:
    """value_and_grad of a scalarized loss through one backend — what a
    training step actually executes for a ``grad`` key, so that is what the
    autotuner must time (a backend that wins the forward shoot-out can lose
    it under vjp: residual stashes and backward structure differ)."""
    base = _build_backend_fn(key, name)
    if key.radius_kind == "batch" and not is_batch_native(name):
        base = jax.vmap(base, in_axes=(0, 0))

    def loss(y, radius):
        return jnp.sum(base(y, radius) ** 2)

    return jax.jit(jax.value_and_grad(loss))


def _autotune(key: PlanKey) -> Tuple[str, Dict[str, float]]:
    """Interleaved min-of-rounds shoot-out over every candidate backend.

    Candidates are timed round-robin (not each in its own block) and the
    minimum per candidate is kept: the fastest rep is the least contaminated
    by scheduler noise, interleaving keeps machine drift from favouring
    whichever candidate ran in a calm window, and a wrong verdict is
    permanent for the process.

    ``grad`` keys time forward+backward (``value_and_grad`` of a scalarized
    loss) instead of the plain call — the verdict that matters for a
    projection differentiated through by training.
    """
    y, radius = _bench_args(key)
    if key.grad:
        fns = {name: _grad_fn(key, name) for name in _candidates(key)}
    else:
        fns = {name: _get_executable(key, name).fn
               for name in _candidates(key)}
    for fn in fns.values():
        for _ in range(2):
            jax.block_until_ready(fn(y, radius))  # compile + warm
    timings: Dict[str, float] = dict.fromkeys(fns, float("inf"))
    for _ in range(_AUTOTUNE_REPS):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(y, radius))
            timings[name] = min(timings[name],
                                (time.perf_counter() - t0) * 1e6)
    winner = min(timings, key=timings.get)
    return winner, timings


def _canonical_backend_name(key: PlanKey, method: str) -> str:
    if method in _SPECIALIZED:
        if not _backend_available(_SPECIALIZED[method], key):
            raise ValueError(
                f"backend {method!r} is not available for shape={key.shape} "
                f"levels={key.levels} radius_kind={key.radius_kind!r} on "
                f"device={key.device!r} (interpret={key.interpret})")
        return method
    try:
        return ball.resolve_method(method)
    except ValueError:
        raise ValueError(
            f"unknown projection backend {method!r}; generic: "
            f"{sorted(ball.available_methods())}, specialized: "
            f"{sorted(_SPECIALIZED)} (or 'auto')") from None


@dataclasses.dataclass(frozen=True, eq=False)
class ProjectionPlan:
    """A shape/dtype-specialized, pre-compiled multi-level projection.

    Call it like a function: ``plan(y, radius)``. ``method`` is the backend
    the planner chose (the autotune winner under ``method="auto"``);
    ``timings_us`` holds the per-candidate micro-benchmark when autotuned.
    """

    key: PlanKey
    method: str                              # chosen backend
    requested: str                           # what the caller asked for
    timings_us: Optional[Dict[str, float]]   # autotune results (auto only)
    _exec: _Executable
    donate: bool = False                     # executable consumes the payload

    def __call__(self, y, radius=1.0):
        y = jnp.asarray(y)
        if self.key.radius_kind == "scalar":
            expected = self.key.shape
        else:
            expected = y.shape[:1] + self.key.shape
        if y.shape != expected:
            raise ValueError(
                f"plan built for shape {self.key.shape} "
                f"(radius_kind={self.key.radius_kind!r}) got {y.shape}")
        if y.dtype.name != self.key.dtype:
            raise ValueError(
                f"plan built for dtype {self.key.dtype} got {y.dtype.name}")
        radius = jnp.asarray(radius, y.dtype)
        if self.key.radius_kind == "batch" and radius.ndim == 0:
            radius = jnp.full((y.shape[0],), radius)
        return self._exec.fn(y, radius)

    @property
    def trace_count(self) -> int:
        """How many times the executable's body has been traced (tests)."""
        return self._exec.traces[0]


def make_plan(shape, dtype, levels, radius_kind: str = "scalar",
              method: str = AUTO, *, interpret: bool = False,
              device: str | None = None, sharding=None,
              donate: bool = False, grad: bool = False) -> ProjectionPlan:
    """Build (or fetch from cache) the projection plan for one workload.

    ``shape``/``dtype`` describe one tensor to project (for
    ``radius_kind="batch"`` the plan executes over a leading batch axis and a
    per-item radius vector, vmap'd; the batch axis is dynamic, so each NEW
    batch size traces once — batch callers should pad to bucket sizes, as the
    serving service does). ``levels`` is the norm design ν of
    ``multilevel_project``. ``method`` is a backend name, or ``"auto"`` to
    micro-benchmark every available backend on first call and cache the
    winner. ``interpret=True`` makes the fused Pallas backends eligible off
    TPU (interpret mode — tests only; never use it for performance).

    ``sharding`` (a committed ``NamedSharding`` or a ``(mesh, spec)`` pair)
    makes the plan mesh-aware: the schedule executor joins the candidate set
    as the ``"sharded"`` backend and the generic candidates are timed on the
    committed sharded input (i.e. as GSPMD gather-and-project), so the
    autotune verdict is schedule-vs-gather by measurement.

    ``donate=True`` jits the executable with ``donate_argnums=(0,)``: the
    payload buffer (the tensor, or the stacked bucket for
    ``radius_kind="batch"``) is consumed in place — the serving engine's
    no-copy path. Donating and non-donating plans share the autotune verdict
    but hold separate executables; callers must not reuse a donated input.

    ``grad=True`` marks a TRAINING key: the workload will be differentiated
    through (the projection sits inside a loss), so under ``method="auto"``
    the autotuner times ``value_and_grad`` of each candidate instead of the
    forward call. Forward and grad keys cache separate verdicts — a backend
    with a cheap forward but an expensive (or recomputing) backward loses
    only the grad shoot-out. The plan's executable is the forward either way
    (it is differentiable; the chosen backend's custom VJP is what the
    enclosing ``jax.grad`` picks up).
    """
    _maybe_register_kernel_backends()
    shape = tuple(int(s) for s in shape)
    dtype = np.dtype(dtype)
    lv = canonical_levels(levels)
    multilevel._check_levels(shape, lv)   # validate the norm design ONCE
    if radius_kind not in _RADIUS_KINDS:
        raise ValueError(
            f"radius_kind must be one of {_RADIUS_KINDS}, got {radius_kind!r}")
    if device is None:
        device = jax.devices()[0].platform
    key = PlanKey(shape, dtype.name, lv, radius_kind, device, bool(interpret),
                  canonical_sharding(sharding, len(shape)), bool(grad))
    cache_key = (key, method, donate)
    if cache_key in _PLANS:
        _count("plan_hits")
        return _PLANS[cache_key]
    _count("plan_misses")
    timings: Optional[Dict[str, float]] = None
    if method == AUTO:
        if key in _AUTO_WINNERS:
            _count("autotune_hits")
            chosen, timings = _AUTO_WINNERS[key]
        else:
            _count("autotune_runs")
            chosen, timings = _autotune(key)
            _AUTO_WINNERS[key] = (chosen, timings)
    else:
        chosen = _canonical_backend_name(key, method)
    plan = ProjectionPlan(key=key, method=chosen, requested=method,
                          timings_us=timings,
                          _exec=_get_executable(key, chosen, donate),
                          donate=donate)
    _PLANS[cache_key] = plan
    return plan


def validate_backend(shape, dtype, levels, method: str, *,
                     device: str | None = None, interpret: bool = False,
                     sharding=None, radius_kind: str = "scalar",
                     grad: bool = False) -> str:
    """Canonicalize + validate a backend name for a workload, without
    building (or autotuning) a plan.

    Returns the canonical name (aliases fold, ``"auto"`` passes through);
    raises ``ValueError`` for an unknown backend or a specialized backend
    that is not available for this (shape, levels, device, radius_kind).
    Cheap enough for a request-admission path — the serving tier calls it
    per submit (with ``radius_kind="batch"`` for unsharded traffic, since
    groups execute as stacked buckets).
    """
    _maybe_register_kernel_backends()
    if method == AUTO:
        return AUTO
    if device is None:
        device = jax.devices()[0].platform
    key = PlanKey(tuple(int(s) for s in shape), np.dtype(dtype).name,
                  canonical_levels(levels), radius_kind, device,
                  bool(interpret), canonical_sharding(sharding, len(shape)),
                  bool(grad))
    return _canonical_backend_name(key, method)


def best_l1_method(n: int, dtype=jnp.float32, *, device: str | None = None,
                   grad: bool = False) -> str:
    """Autotuned θ-solver name for flat length-``n`` ℓ1 projections.

    Build-time helper for call sites that need a *generic* backend name (the
    sharded projection, the training hook): only ``core.ball`` registry
    methods compete, so the winner is always embeddable under an enclosing
    jit/vmap/shard_map. ``grad=True`` makes it a training key — the shoot-out
    times each θ-solver under ``value_and_grad`` (solvers differ much more in
    backward cost than forward: sort-based ones backprop through the sort).
    """
    plan = make_plan((int(n),), dtype, [("1", 1)], method=AUTO, device=device,
                     grad=grad)
    return plan.method


def maybe_plan_call(y, levels, radius):
    """Eager ``method="auto"`` dispatch for the core entry points.

    Returns the projected array when ``y`` is concrete (plan built/cached and
    executed), or ``None`` when ``y`` is a tracer — the caller then falls back
    to :func:`best_l1_method` on the (always static) shape. A committed
    mesh-sharded array routes to a mesh-aware plan (the sharded schedule
    executor competes against GSPMD gather-and-project in its autotune).
    """
    if isinstance(y, jax.core.Tracer):
        return None
    sharding = getattr(y, "sharding", None)
    if not isinstance(sharding, jax.sharding.NamedSharding):
        sharding = None
    plan = make_plan(jnp.shape(y), jnp.asarray(y).dtype, levels, method=AUTO,
                     sharding=sharding)
    return plan(y, radius)
