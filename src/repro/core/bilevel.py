"""Bi-level ℓp,q projections (paper §3–5, Algorithms 1–4 and 7).

``BP^{p,q}_η(Y)`` for Y ∈ R^{n×m} (columns of length n, matching the paper):

    1. aggregate:  v_q[j] = ‖Y[:, j]‖_q                      (O(nm), parallel over j,i)
    2. outer:      u = P^p_η(v_q)                            (O(m) – tiny)
    3. inner:      X[:, j] = P^q_{u[j]}(Y[:, j]) for every j (O(nm), parallel over j)

The result is always feasible (‖X‖_{p,q} ≤ η) and reached in ONE pass — no
bi-level iteration. For q = ∞ step 3 is a clip; for q = 2 a rescale; for q = 1
a per-column soft-threshold with per-column radius.

Everything here is jit-safe and works on any 2-D array; use
``bilevel_project_axes`` for arbitrary tensors/axes (used by the training-time
projection hook where weight matrices are (d_in, d_out) etc.).

``method`` selects the ℓ1 θ-solver backend (see ``ball.available_methods()``);
all per-norm dispatch is delegated to the tables in ``core.ball``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import ball


def _outer_project(v: jax.Array, p, radius, method: str) -> jax.Array:
    """Project the (non-negative) aggregate vector v onto the p-ball."""
    return ball.project_ball(v, p, radius, method=method)


def _inner_project_cols(y: jax.Array, q, u: jax.Array, method: str) -> jax.Array:
    """Project every column y[:, j] onto the q-ball of radius u[j]."""
    return ball.project_grouped(y, q, u, inner_axes=(0,), method=method)


def bilevel_project(y: jax.Array, radius, p=1, q=jnp.inf, method: str = "sort") -> jax.Array:
    """BP^{p,q}_radius(Y) for a 2-D Y, aggregating columns (axis 0).

    ``method="auto"``: a bi-level projection IS the two-level norm design
    ν = [(q, 1), (p, 1)], so auto routes through the planner exactly like
    ``multilevel_project`` (cached autotuned plan when eager, best generic
    θ-solver for the aggregate length when traced).
    """
    if y.ndim != 2:
        raise ValueError("bilevel_project expects a 2-D array; use bilevel_project_axes")
    if method == "auto":
        from . import multilevel

        return multilevel.multilevel_project(y, [(q, 1), (p, 1)], radius,
                                             method="auto")
    method = ball.resolve_method(method)
    v = ball.norm_reduce(y, q, axes=0)  # (m,) non-negative
    u = _outer_project(v, p, radius, method)
    # outer projection of a non-negative vector stays non-negative for p in {1,2,inf}
    return _inner_project_cols(y, q, u, method)


def bilevel_l1inf(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """Paper Algorithm 2: v = colwise max|·| → P¹(v) → clip. O(nm), depth O(n+m)."""
    return bilevel_project(y, radius, p=1, q=jnp.inf, method=method)


def bilevel_l11(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """Paper Algorithm 3."""
    return bilevel_project(y, radius, p=1, q=1, method=method)


def bilevel_l12(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """Paper Algorithm 4 (group-LASSO-flavoured; different optimum from it)."""
    return bilevel_project(y, radius, p=1, q=2, method=method)


def bilevel_l21(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """Paper Algorithm 7 (exclusive-LASSO-flavoured)."""
    return bilevel_project(y, radius, p=2, q=1, method=method)


def bilevel_project_axes(y: jax.Array, radius, p=1, q=jnp.inf, *, inner_axes,
                         method: str = "sort") -> jax.Array:
    """Bi-level projection of an arbitrary tensor.

    ``inner_axes`` are aggregated by the q-norm (the "column" axes); all other
    axes index the groups whose aggregate is projected onto the p-ball.
    Equivalent to reshaping to 2-D, projecting, and reshaping back — but done
    with broadcasting so it fuses well. ``method="auto"`` autotunes the outer
    θ-solver on the aggregate-vector length (generic backends only — the
    arbitrary-axes form has no fused kernel).
    """
    if method == "auto":
        from . import plan as _plan

        inner = tuple(ax % y.ndim for ax in inner_axes)
        n_outer = math.prod(d for a, d in enumerate(y.shape) if a not in inner)
        method = _plan.best_l1_method(max(n_outer, 1), y.dtype)
    method = ball.resolve_method(method)
    inner_axes = tuple(a % y.ndim for a in inner_axes)
    v = ball.norm_reduce(y, q, axes=inner_axes)  # shape = outer dims
    u_flat = _outer_project(v.reshape(-1), p, radius, method)
    u = u_flat.reshape(v.shape)
    return ball.project_grouped(y, q, u, inner_axes=inner_axes, method=method)
