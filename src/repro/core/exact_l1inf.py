"""EXACT Euclidean projection onto the ℓ1,∞ ball — the paper's baseline.

The paper compares its bi-level projection against the exact projection of
Chu et al. (ICML'20, semismooth Newton). We re-derive that algorithm in a
TPU/JAX-idiomatic form (see DESIGN.md §4):

    minimize ½‖X-Y‖²  s.t.  Σ_j max_i |X_ij| ≤ η

Work with A = |Y|. The solution is X_ij = sign(Y_ij)·min(A_ij, t_j) where the
column caps t_j solve, for a dual variable λ ≥ 0,

    Σ_i max(A_ij - t_j, 0) = λ     (or t_j = 0 when Σ_i A_ij ≤ λ)
    Σ_j t_j = η.

With each column sorted descending (a_1 ≥ … ≥ a_n, prefix sums S_k) and
d_k = S_k - k·a_k (non-decreasing in k), the inner solve is

    k*(λ) = max{k : d_k ≤ λ},   t(λ) = max((S_{k*} - λ)/k*, 0),

and F(λ) = Σ_j t_j(λ) - η is convex, piecewise-linear, strictly decreasing on
the active region with F'(λ) = -Σ_{j active} 1/k*_j. Newton iteration from
λ=0 converges monotonically (semismooth Newton, matching Chu et al.). Every
iteration is a batched count + gather: fully data-parallel.

Axis convention: *columns are the last axis's groups*; i.e. for Y of shape
(n, m) we project m columns each of length n — matching the paper. The
functions below accept (n, m) and reduce over axis 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEWTON_ITERS = 50


def l1inf_norm(y: jax.Array) -> jax.Array:
    """‖Y‖_{1,∞} = Σ_j max_i |Y_ij| for Y of shape (n, m)."""
    return jnp.sum(jnp.max(jnp.abs(y), axis=0))


def _caps_for_lambda(lam, a_sorted_desc, csum, dks, n):
    """t_j(λ) and the active segment count k*_j(λ), vectorized over columns.

    a_sorted_desc : (n, m) columns sorted descending
    csum          : (n, m) prefix sums of a_sorted_desc
    dks           : (n, m) d_k = S_k - k*a_k  (non-decreasing down each column)
    """
    # k* = #{k : d_k <= lam} ; always >= 1 because d_1 = 0 <= lam
    k = jnp.sum(dks <= lam, axis=0)
    k = jnp.maximum(k, 1)
    sk = jnp.take_along_axis(csum, (k - 1)[None, :], axis=0)[0]
    t = (sk - lam) / k.astype(a_sorted_desc.dtype)
    t = jnp.maximum(t, 0.0)
    # columns whose total mass <= lam are fully shrunk to cap 0
    total = csum[-1]
    t = jnp.where(total <= lam, 0.0, t)
    active = (t > 0).astype(a_sorted_desc.dtype)
    dF = -jnp.sum(active / k.astype(a_sorted_desc.dtype))
    return t, dF


def _sorted_column_stats(a: jax.Array):
    """(a_sorted_desc, csum, dks) shared by every dual solver."""
    n = a.shape[0]
    a_sorted = jnp.sort(a, axis=0)[::-1, :]  # descending per column
    csum = jnp.cumsum(a_sorted, axis=0)
    ks = jnp.arange(1, n + 1, dtype=jnp.float32)[:, None]
    dks = csum - ks * a_sorted  # d_k, non-decreasing in k
    return a_sorted, csum, dks


def _solve_lambda_newton(a, a_sorted, csum, dks, radius, iters):
    """Semismooth-Newton on F(λ) = Σ t_j(λ) - η, monotone from λ=0."""
    n = a.shape[0]

    def newton_body(_, lam):
        t, dF = _caps_for_lambda(lam, a_sorted, csum, dks, n)
        F = jnp.sum(t) - radius
        # dF < 0 whenever F > 0 (at least one active column); guard anyway.
        step = F / jnp.where(dF >= -1e-20, -1e-20, dF)
        lam_next = lam - step
        return jnp.maximum(lam_next, 0.0)

    return jax.lax.fori_loop(0, iters, newton_body, jnp.zeros((), jnp.float32))


def _solve_lambda_bisect(a, a_sorted, csum, dks, radius, iters):
    """Bisection on F(λ) (slower, very robust — the cross-check oracle)."""
    n = a.shape[0]
    lo = jnp.zeros((), jnp.float32)
    hi = jnp.sum(jnp.max(a, axis=0))  # F(hi) <= 0 since every t_j(hi) = 0… (g <= S_n <= hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        F = jnp.sum(_caps_for_lambda(mid, a_sorted, csum, dks, n)[0]) - radius
        return jnp.where(F > 0, mid, lo), jnp.where(F > 0, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


# dual-λ solver registry — same shape as core.ball's ℓ1 backend table so a new
# root-finder (e.g. the Newton variant of https://arxiv.org/pdf/1806.10041) is
# one entry here, not a new public function.
_DUAL_SOLVERS = {
    "newton": (_solve_lambda_newton, _NEWTON_ITERS),
    "bisect": (_solve_lambda_bisect, 100),
}


def resolve_dual_solver(method: str) -> str:
    if method not in _DUAL_SOLVERS:
        raise ValueError(
            f"unknown l1inf dual solver {method!r}; available: {sorted(_DUAL_SOLVERS)}"
        )
    return method


def project_l1inf_exact(y: jax.Array, radius, iters: int | None = None,
                        method: str = "newton") -> jax.Array:
    """Exact projection of Y (n, m) onto the ℓ1,∞ ball of ``radius``.

    ``method`` selects the dual-λ root search: "newton" (semismooth Newton,
    default) or "bisect". Returns Y unchanged when already feasible. fp32
    recommended (sorting + prefix sums).
    """
    solver, default_iters = _DUAL_SOLVERS[resolve_dual_solver(method)]
    orig_dtype = y.dtype
    yf = y.astype(jnp.float32)
    a = jnp.abs(yf)
    n = a.shape[0]
    radius = jnp.asarray(radius, jnp.float32)

    a_sorted, csum, dks = _sorted_column_stats(a)
    lam = solver(a, a_sorted, csum, dks, radius,
                 default_iters if iters is None else iters)
    t, _ = _caps_for_lambda(lam, a_sorted, csum, dks, n)

    x = jnp.sign(yf) * jnp.minimum(a, t[None, :])
    feasible = l1inf_norm(yf) <= radius
    return jnp.where(feasible, yf, x).astype(orig_dtype)


def project_l1inf_exact_bisect(y: jax.Array, radius, iters: int = 100) -> jax.Array:
    """Bisection variant (cross-check oracle for tests)."""
    return project_l1inf_exact(y, radius, iters=iters, method="bisect")
