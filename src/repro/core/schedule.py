"""Schedule IR for multi-level projections — compile ν, then execute anywhere.

``multilevel_project`` used to be a recursion (Algorithm 6 verbatim). Every
consumer that is *not* a single-device eager call — the planner's fused
backends, the mesh executor in ``core/sharded.py``, the collective-bytes
model — needs the same information the recursion only exposes implicitly:
which reduce feeds which apply, what the aggregate shapes are, and where the
single tiny θ-solve sits. This module makes that structure explicit.

A norm design ``levels = [(q₁, k₁), ..., (q_L, k_L)]`` compiles to the flat
step list

    ReduceLevel(q₁, axes₁) → … → ReduceLevel(q_{L-1}, axes_{L-1})
        → OuterSolve(q_L)
    → ApplyGroup(q_{L-1}, axes_{L-1}) → … → ApplyGroup(q₁, axes₁)

i.e. a forward sweep of norm aggregations, ONE vector projection on the fully
aggregated (tiny) tensor, and a backward sweep of group-wise applies that
re-uses the forward aggregates (the ℓ2 apply is a rescale by the *saved*
group norm; the ℓ∞ apply is a clip; only a ℓ1 apply needs per-group θ-solves).
Executors differ only in where each step runs:

* :func:`execute` — single device / inside jit (what ``multilevel_project``
  now calls instead of recursing);
* ``core.sharded.multilevel_project_sharded`` — the same schedule under
  ``shard_map``: reduces combine across the mesh with one collective per
  sharded level, the OuterSolve gathers only the final aggregate, applies
  stay local (DESIGN.md §3);
* the fused Pallas planner backends, which pattern-match whole schedules.

``batch_dims`` prepends carried-through axes: the leading ``batch_dims`` axes
are outer axes of every level and the OuterSolve runs batched over them (the
execution mode of the training hook, where a stacked (layers, …) weight
projects each trailing block independently).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.obs import profile as obs_profile

from . import ball

Level = Tuple[object, int]


class ReduceLevel(NamedTuple):
    """Aggregate ``axes`` of the current tensor with ``norm`` (forward sweep)."""

    norm: str                 # canonical '1' | '2' | 'inf'
    axes: Tuple[int, ...]     # absolute axes in this step's input tensor


class OuterSolve(NamedTuple):
    """Project the fully-aggregated tensor (flattened past the batch axes)
    onto the ``norm``-ball — the single tiny θ-solve of the whole design."""

    norm: str


class ApplyGroup(NamedTuple):
    """Shrink each group (a slice over ``axes``) of the matching reduce's
    input to the radius computed one level up (backward sweep)."""

    norm: str
    axes: Tuple[int, ...]


Step = Union[ReduceLevel, OuterSolve, ApplyGroup]


class Schedule(NamedTuple):
    """A compiled norm design: the step list plus its static shape plan.

    ``stage_shapes[i]`` is the input shape of the i-th reduce (so
    ``stage_shapes[0]`` is the tensor shape and ``stage_shapes[-1]`` the shape
    the OuterSolve sees, batch axes included).
    """

    shape: Tuple[int, ...]
    batch_dims: int
    levels: Tuple[Tuple[str, int], ...]
    steps: Tuple[Step, ...]
    stage_shapes: Tuple[Tuple[int, ...], ...]

    @property
    def reduces(self) -> Tuple[ReduceLevel, ...]:
        return tuple(s for s in self.steps if isinstance(s, ReduceLevel))

    @property
    def applies(self) -> Tuple[ApplyGroup, ...]:
        return tuple(s for s in self.steps if isinstance(s, ApplyGroup))

    @property
    def solve(self) -> OuterSolve:
        return next(s for s in self.steps if isinstance(s, OuterSolve))

    @property
    def solve_size(self) -> int:
        """Length of the vector the OuterSolve's θ-solver sees (per batch
        element) — the planner's autotune key for the generic backends."""
        lead = self.stage_shapes[-1][self.batch_dims:]
        return math.prod(lead) if lead else 1

    @property
    def level_group_sizes(self) -> Tuple[int, ...]:
        """Aggregated extent g_t of each ReduceLevel (the product of its axes'
        dims in its stage input) — the group length of the matching apply."""
        sizes = []
        for i, red in enumerate(self.reduces):
            shp = self.stage_shapes[i]
            sizes.append(math.prod(shp[a] for a in red.axes))
        return tuple(sizes)

    @property
    def canonical_shape(self) -> Tuple[int, ...]:
        """The collapsed view ``batch… + (g_1, …, g_{L-1}, solve_size)``.

        Each reduce level's axes fuse into one axis and the surviving axes
        flatten into the lane axis. Every level's axes are contiguous and in
        order, so the reshape is free — this is the shape the kernel code
        generator (``kernels/codegen``) tiles, and ``canonical_stage_shapes``
        gives the matching per-stage views the tiler sizes VMEM blocks from.
        """
        batch = self.shape[:self.batch_dims]
        return batch + self.level_group_sizes + (self.solve_size,)

    @property
    def canonical_stage_shapes(self) -> Tuple[Tuple[int, ...], ...]:
        """Collapsed ``stage_shapes``: entry i is the canonical input shape of
        the i-th reduce (entry -1 is what the OuterSolve sees)."""
        canon = self.canonical_shape
        b = self.batch_dims
        return tuple(canon[:b] + canon[b + i:]
                     for i in range(len(self.reduces) + 1))


def canonical_levels(levels: Sequence[Level]) -> Tuple[Tuple[str, int], ...]:
    """Canonicalize a norm design to ``(('1'|'2'|'inf', n_axes), ...)``."""
    return tuple((ball.canonical_norm(q), int(k)) for q, k in levels)


def check_levels(shape, levels: Sequence[Level], batch_dims: int = 0) -> None:
    """Validate that ν covers exactly the non-batch axes of ``shape``."""
    total = sum(k for _, k in levels)
    if total != len(shape) - batch_dims:
        covered = f"{len(shape)} - {batch_dims} batch" if batch_dims \
            else str(len(shape))
        raise ValueError(
            f"norm design {list(levels)} covers {total} axes but tensor has "
            f"{covered}")
    for _, k in levels:
        if k < 1:
            raise ValueError("each level must aggregate at least one axis")


@functools.lru_cache(maxsize=None)
def _compile_cached(shape, levels, batch_dims):
    check_levels(shape, levels, batch_dims)
    b = batch_dims
    steps = []
    stage_shapes = [shape]
    cur = shape
    for q, k in levels[:-1]:
        axes = tuple(range(b, b + k))
        steps.append(ReduceLevel(q, axes))
        cur = cur[:b] + cur[b + k:]
        stage_shapes.append(cur)
    steps.append(OuterSolve(levels[-1][0]))
    for (q, k), red in zip(reversed(levels[:-1]), reversed(steps[:-1])):
        steps.append(ApplyGroup(q, red.axes))
    return Schedule(shape, b, levels, tuple(steps), tuple(stage_shapes))


def compile_schedule(shape, levels: Sequence[Level],
                     batch_dims: int = 0) -> Schedule:
    """Lower a norm design against a shape into a reduce/solve/apply schedule."""
    return _compile_cached(tuple(int(s) for s in shape),
                           canonical_levels(levels), int(batch_dims))


# --------------------------------------------------------------------------- #
# Step primitives shared by the local and the sharded executor
# --------------------------------------------------------------------------- #


def apply_group(y: jax.Array, norm: str, radii: jax.Array, axes,
                agg: Optional[jax.Array], method: str) -> jax.Array:
    """One ApplyGroup step: shrink each group of ``y`` to its radius.

    ``agg`` is the matching forward aggregate (the group norms). The ℓ2 apply
    rescales by it instead of recomputing the norm — on a mesh the saved
    aggregate is already the *global* group norm, so the apply needs no
    further communication; locally it just saves a reduction.
    """
    if norm == "inf":
        u_b = jnp.expand_dims(radii, axes)
        return jnp.clip(y, -u_b, u_b)
    if norm == "2" and agg is not None:
        scale = jnp.where(agg > radii, radii / jnp.maximum(agg, 1e-30), 1.0)
        return y * jnp.expand_dims(scale, axes)
    return ball.project_grouped(y, norm, radii, inner_axes=axes, method=method)


def solve_outer(top: jax.Array, norm: str, radius, batch_dims: int,
                method: str) -> jax.Array:
    """The OuterSolve: flatten past the batch axes, project, restore shape."""
    lead = top.shape[:batch_dims]
    flat = top.reshape(lead + (-1,))
    return ball.project_ball(flat, norm, radius, method=method).reshape(top.shape)


def execute(y: jax.Array, sched: Schedule, radius,
            method: str = "sort") -> jax.Array:
    """Run a compiled schedule on one device (or inside an enclosing jit).

    Forward sweep saves every reduce input and output; the OuterSolve runs on
    the final aggregate; the backward sweep re-applies through the saved
    stages. Identical math to the old recursion — the property tests assert
    the feasibility invariant either way.
    """
    method = ball.resolve_method(method)
    inputs = [y]
    aggs = []
    for t, red in enumerate(sched.reduces):
        with obs_profile.stage_scope(red, t):
            v = ball.norm_reduce(inputs[-1], red.norm, axes=red.axes)
        aggs.append(v)
        inputs.append(v)
    with obs_profile.stage_scope(sched.solve):
        w = solve_outer(inputs[-1], sched.solve.norm, radius,
                        sched.batch_dims, method)
    for i, app in zip(reversed(range(len(aggs))), sched.applies):
        with obs_profile.stage_scope(app, i):
            w = apply_group(inputs[i], app.norm, w, app.axes, aggs[i], method)
    return w


# --------------------------------------------------------------------------- #
# Collective-bytes model (DESIGN.md §3, generalized to arbitrary ν)
# --------------------------------------------------------------------------- #

_L1_APPLY_SWEEPS = 65  # distributed bisect: 64 φ-psums + the initial pmax


def sharded_collective_bytes(shape, levels: Sequence[Level], spec,
                             mesh_sizes, itemsize: int = 4) -> dict:
    """Per-step collective payload of the sharded schedule vs gather-and-project.

    ``spec`` maps each tensor axis to a mesh axis name (or None); ``mesh_sizes``
    maps mesh axis names to their device counts. Payload bytes count what a
    collective moves per device pair-step (matching ``fig4_coll_bytes_*``):

    * a ReduceLevel over a sharded axis all-reduces its *output* aggregate;
    * the OuterSolve all-gathers the final aggregate iff a sharded axis
      survives every reduce (otherwise it is already replicated);
    * an ℓ∞/ℓ2 ApplyGroup is local (clip / saved-aggregate rescale);
      an ℓ1 ApplyGroup whose group spans a sharded axis runs the distributed
      bisect — ``_L1_APPLY_SWEEPS`` small collectives over the group count.

    Gather-and-project moves the whole tensor. The per-level ratio is the
    aggregated extent — Proposition 6.4's speedup as bytes.
    """
    sched = compile_schedule(shape, levels)
    names = [spec[a] if a < len(spec) else None for a in range(len(shape))]
    steps = []
    cur_names = list(names)
    for red in sched.reduces:
        out_shape = [d for a, d in enumerate(sched.stage_shapes[len(steps)])
                     if a not in red.axes]
        coll = [cur_names[a] for a in red.axes if cur_names[a]]
        payload = math.prod(out_shape) * itemsize if coll else 0
        steps.append({"step": f"reduce_{red.norm}", "bytes": payload})
        cur_names = [n for a, n in enumerate(cur_names) if a not in red.axes]
    solve_payload = 0
    if any(cur_names):
        solve_payload = math.prod(sched.stage_shapes[-1]) * itemsize
    steps.append({"step": f"solve_{sched.solve.norm}", "bytes": solve_payload})
    apply_names = list(names)
    stage_name_list = [list(names)]
    for red in sched.reduces:
        apply_names = [n for a, n in enumerate(apply_names)
                       if a not in red.axes]
        stage_name_list.append(list(apply_names))
    for i, app in zip(reversed(range(len(sched.reduces))), sched.applies):
        coll = [stage_name_list[i][a] for a in app.axes if stage_name_list[i][a]]
        if app.norm == "1" and coll:
            groups = math.prod(sched.stage_shapes[i + 1])
            payload = groups * itemsize * _L1_APPLY_SWEEPS
        else:
            payload = 0
        steps.append({"step": f"apply_{app.norm}", "bytes": payload})
    total = sum(s["bytes"] for s in steps)
    gathered = math.prod(shape) * itemsize
    return {
        "per_step": steps,
        "schedule_bytes": total,
        "gather_bytes": gathered,
        "ratio": gathered / max(total, 1),
    }
