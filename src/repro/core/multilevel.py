"""Multi-level projection MP^ν (paper §6, Definitions 6.1/6.2, Algorithms 5/6/10).

A *level* is ``(norm, n_axes)``: aggregate the leading ``n_axes`` axes of the
current tensor with ``norm``. The norm list ν runs innermost→outermost; the
LAST entry is the final vector projection (its n_axes must flatten whatever
remains). Examples for Y ∈ R^{c,n,m}:

    ν = [(inf, 1), (1, 2)]            — bi-level ℓ1,∞ over a matrix-like view
    ν = [(inf, 1), (inf, 1), (1, 1)]  — tri-level ℓ1,∞,∞ of Definition 6.1
    ν = [(1, 3)]                      — |ν| = 1 → the usual flat ℓ1 projection
                                        (Proposition 6.3: MP generalizes P)

Algorithm 6's recursion is compiled to a flat reduce → solve → apply schedule
(``core.schedule``) and executed from that — the same schedule the mesh
executor (``core.sharded``) runs under shard_map and the fused Pallas planner
backends pattern-match.

Complexity: work = O(Π d) (one touch per element per level boundary it lives
under), depth with infinite parallelism = O(Σ levels' reduction depths) —
Proposition 6.4's exponential speedup; on a TPU mesh the outer levels shrink
the data by the aggregated dims, so only the innermost level touches the full
tensor (see core/sharded.py for the mesh mapping).
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from . import ball, schedule as sched_mod

Level = Tuple[object, int]  # (norm ∈ {1,2,'inf',jnp.inf}, number of leading axes)


def _check_levels(shape, levels: Sequence[Level]):
    sched_mod.check_levels(shape, levels)


def _final_level_size(shape, levels: Sequence[Level]) -> int:
    """Length of the vector the LAST level's θ-solver sees (autotune key)."""
    return sched_mod.compile_schedule(shape, levels).solve_size


def multilevel_project(y: jax.Array, levels: Sequence[Level], radius,
                       method: str = "sort") -> jax.Array:
    """MP^ν_radius(Y) — Algorithm 6 via the compiled schedule.

    ``method="auto"`` routes through the projection planner (``core.plan``):
    on a concrete array the cached, autotuned plan executes directly (a
    committed mesh-sharded array routes to the sharded schedule executor);
    under a trace (inside an enclosing jit/vmap) the shape-autotuned best
    *generic* θ-solver is inlined instead (specialized fused backends can't
    be embedded in someone else's trace).
    """
    if method == "auto":
        from . import plan as _plan

        out = _plan.maybe_plan_call(y, levels, radius)
        if out is not None:
            return out
        method = _plan.best_l1_method(_final_level_size(y.shape, levels), y.dtype)
    sched = sched_mod.compile_schedule(y.shape, levels)
    return sched_mod.execute(y, sched, radius, method=method)


def trilevel_l1infinf(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """Paper Algorithm 5: TP^{1,∞,∞} for an order-3 tensor (c, n, m)."""
    if y.ndim != 3:
        raise ValueError("trilevel_l1infinf expects an order-3 tensor")
    return multilevel_project(y, [(jnp.inf, 1), (jnp.inf, 1), (1, 1)], radius, method)


def trilevel_l111(y: jax.Array, radius, method: str = "sort") -> jax.Array:
    """ℓ1,1,1 tri-level used in the paper's Figure 3 benchmark."""
    if y.ndim != 3:
        raise ValueError("trilevel_l111 expects an order-3 tensor")
    return multilevel_project(y, [(1, 1), (1, 1), (1, 1)], radius, method)


def multilevel_norm(x: jax.Array, levels: Sequence[Level]) -> jax.Array:
    """The mixed norm induced by ν: aggregate each level in turn.

    The feasibility invariant of the multi-level projection is
    ``multilevel_norm(MP^ν_η(Y), ν) <= η`` (checked by the property tests).
    """
    _check_levels(x.shape, levels)
    cur = x
    for q, k in levels[:-1]:
        cur = ball.norm_reduce(cur, q, axes=tuple(range(k)))
    q, _ = levels[-1]
    return ball.norm_reduce(cur.reshape(-1), q, axes=0)


def work_depth(shape, levels: Sequence[Level]):
    """(work, depth) model of Prop 6.4 — the modelled sweep behind
    ``benchmarks/projections.py::fig4_parallel`` (section ``fig4`` of
    ``benchmarks.run``).

    work  = sequential element touches; depth = longest dependency chain with
    unbounded parallelism (tree reductions = log2 of the reduced extent).
    """
    _check_levels(shape, levels)
    work = 0
    depth = 0.0
    cur = list(shape)
    for q, k in levels[:-1]:
        red = math.prod(cur[:k])
        rest = math.prod(cur[k:])
        work += red * rest          # aggregation pass
        work += red * rest          # final per-group projection pass
        depth += math.log2(max(red, 2))  # tree-reduce the aggregated axes
        depth += 1                  # the elementwise apply
        cur = cur[k:]
    n = math.prod(cur)
    work += n * int(math.log2(max(n, 2)))  # final vector projection (sort-based)
    depth += math.log2(max(n, 2))
    return work, depth
