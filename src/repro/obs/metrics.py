"""Metrics core: counters, gauges, fixed-bucket histograms — stdlib only.

Model (a deliberately tiny subset of the Prometheus data model):

* a :class:`Registry` owns metric *families*; a family has a name, a help
  string, and a fixed tuple of label names;
* ``family.labels(key=value, ...)`` returns the child for one label
  combination (created on first use, cached); a family with no label names
  IS its own child, so ``registry.counter("x").inc()`` just works;
* every mutation takes the registry's single lock — counters are exact
  under concurrency by construction (the serving dispatcher, the plan
  warm pool, and test hammers all write from their own threads);
* :meth:`Registry.snapshot` renders everything to nested plain dicts, and
  the two exporters (:meth:`Registry.to_jsonl`,
  :meth:`Registry.to_prometheus`) are pure functions of that snapshot.

Histograms are fixed-bucket (cumulative counts per upper bound, plus sum
and count), so ``observe()`` is O(#buckets) with no allocation — cheap
enough for the serving hot path — and :meth:`Histogram.quantile` gives the
standard bucket-interpolated estimate that ``ProjectionEngine.stats()``
reports p50/p99 from.

>>> from repro.obs import metrics
>>> reg = metrics.Registry()
>>> c = reg.counter("requests_total", "handled requests", labels=("route",))
>>> c.labels(route="submit").inc()
>>> c.labels(route="submit").inc(2)
>>> reg.snapshot()["requests_total"]["values"]
[{'labels': {'route': 'submit'}, 'value': 3}]
"""

from __future__ import annotations

import contextlib
import io
import json
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

# default latency buckets (seconds): 100µs .. 30s, roughly ×3 apart —
# wide enough for interpret-mode CPU runs, tight enough for p99 estimates
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0,
                   10.0, 30.0)

LabelValues = Tuple[str, ...]


class _Child:
    """One (family, label-values) series. Base for the three metric kinds."""

    __slots__ = ("_lock", "labelvalues")

    def __init__(self, lock: threading.Lock, labelvalues: LabelValues):
        self._lock = lock
        self.labelvalues = labelvalues


class Counter(_Child):
    """Monotonic counter: ``inc(n)`` with n >= 0."""

    __slots__ = ("_value",)

    def __init__(self, lock, labelvalues):
        super().__init__(lock, labelvalues)
        self._value = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge(_Child):
    """Point-in-time value: ``set(v)`` / ``add(d)``."""

    __slots__ = ("_value",)

    def __init__(self, lock, labelvalues):
        super().__init__(lock, labelvalues)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, d: float) -> None:
        with self._lock:
            self._value += float(d)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Child):
    """Fixed-bucket histogram: cumulative bucket counts + sum + count."""

    __slots__ = ("buckets", "_counts", "_sum", "_count")

    def __init__(self, lock, labelvalues, buckets: Sequence[float]):
        super().__init__(lock, labelvalues)
        self.buckets = tuple(buckets)
        self._counts = [0] * (len(self.buckets) + 1)  # last = +Inf overflow
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, ub in enumerate(self.buckets):  # noqa: B007
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 <= q <= 1).

        Returns 0.0 for an empty histogram. Values past the last bucket
        clamp to the last finite upper bound (the usual Prometheus
        ``histogram_quantile`` behaviour).
        """
        with self._lock:
            counts, total = list(self._counts), self._count
        if total == 0:
            return 0.0
        rank = q * total
        seen = 0.0
        lo = 0.0
        for i, ub in enumerate(self.buckets):
            if seen + counts[i] >= rank:
                frac = 0.0 if counts[i] == 0 else (rank - seen) / counts[i]
                return lo + frac * (ub - lo)
            seen += counts[i]
            lo = ub
        return self.buckets[-1] if self.buckets else 0.0


class _Family:
    """A named metric family: labels -> child registry."""

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Tuple[str, ...], lock: threading.Lock,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = labelnames
        self.buckets = tuple(buckets) if buckets is not None else None
        self._lock = lock
        self._children: Dict[LabelValues, _Child] = {}
        if not labelnames:
            self._default = self._make(())
            self._children[()] = self._default

    def _make(self, labelvalues: LabelValues) -> _Child:
        if self.kind == "counter":
            return Counter(self._lock, labelvalues)
        if self.kind == "gauge":
            return Gauge(self._lock, labelvalues)
        return Histogram(self._lock, labelvalues, self.buckets)

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(kv)}")
        values = tuple(str(kv[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(values)
            if child is None:
                child = self._make(values)
                self._children[values] = child
        return child

    # ---- label-free convenience: the family proxies its default child ----
    def _default_child(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} has labels {self.labelnames}; use .labels()")
        return self._default

    def inc(self, n: float = 1):
        self._default_child().inc(n)

    def set(self, v: float):
        self._default_child().set(v)

    def add(self, d: float):
        self._default_child().add(d)

    def observe(self, v: float):
        self._default_child().observe(v)

    @property
    def value(self):
        return self._default_child().value

    @property
    def count(self) -> int:
        return self._default_child().count

    @property
    def sum(self) -> float:
        return self._default_child().sum

    def quantile(self, q: float) -> float:
        return self._default_child().quantile(q)

    def children(self) -> Iterable[_Child]:
        with self._lock:
            return list(self._children.values())


class Registry:
    """Holds metric families; one lock guards every mutation (exactness
    beats micro-contention at the rates projection serving runs at)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    def _get_or_create(self, name: str, help: str, kind: str,
                       labels: Tuple[str, ...],
                       buckets: Optional[Sequence[float]] = None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labels:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind} "
                        f"with labels {fam.labelnames}")
                return fam
            fam = _Family(name, help, kind, labels, threading.Lock(), buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._get_or_create(name, help, "counter", tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._get_or_create(name, help, "gauge", tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Family:
        return self._get_or_create(name, help, "histogram", tuple(labels),
                                   buckets)

    def clear(self) -> None:
        """Drop every family (tests / bench isolation)."""
        with self._lock:
            self._families.clear()

    # ------------------------------------------------------------ export

    def snapshot(self) -> Dict[str, dict]:
        """Nested plain-dict view of every series (JSON-serializable)."""
        with self._lock:
            families = list(self._families.values())
        out: Dict[str, dict] = {}
        for fam in families:
            values = []
            for child in fam.children():
                labels = dict(zip(fam.labelnames, child.labelvalues))
                if fam.kind == "histogram":
                    with child._lock:
                        counts = list(child._counts)
                        s, n = child._sum, child._count
                    values.append({"labels": labels,
                                   "buckets": list(fam.buckets),
                                   "counts": counts, "sum": s, "count": n})
                else:
                    values.append({"labels": labels, "value": child.value})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "values": values}
        return out

    def to_jsonl(self) -> str:
        """One JSON object per line: ``{"name", "kind", "labels", ...}``."""
        lines = []
        for name, fam in sorted(self.snapshot().items()):
            for v in fam["values"]:
                row = {"name": name, "kind": fam["kind"]}
                row.update(v)
                lines.append(json.dumps(row, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        buf = io.StringIO()
        for name, fam in sorted(self.snapshot().items()):
            if fam["help"]:
                buf.write(f"# HELP {name} {fam['help']}\n")
            buf.write(f"# TYPE {name} {fam['kind']}\n")
            for v in fam["values"]:
                if fam["kind"] == "histogram":
                    cum = 0
                    for ub, cnt in zip(v["buckets"] + [float("inf")],
                                       v["counts"]):
                        cum += cnt
                        le = "+Inf" if ub == float("inf") else repr(ub)
                        lbl = _fmt_labels({**v["labels"], "le": le})
                        buf.write(f"{name}_bucket{lbl} {cum}\n")
                    lbl = _fmt_labels(v["labels"])
                    buf.write(f"{name}_sum{lbl} {v['sum']}\n")
                    buf.write(f"{name}_count{lbl} {v['count']}\n")
                else:
                    lbl = _fmt_labels(v["labels"])
                    buf.write(f"{name}{lbl} {v['value']}\n")
        return buf.getvalue()


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


@contextlib.contextmanager
def timed(hist, **labels):
    """Time a block into a histogram (seconds): ``with timed(h): work()``.

    ``hist`` is a histogram family or child; keyword labels select the
    child. The observation happens even when the block raises — a failing
    dispatch still took the time it took.
    """
    child = hist.labels(**labels) if labels else hist
    t0 = time.perf_counter()
    try:
        yield
    finally:
        child.observe(time.perf_counter() - t0)


# process-global default registry — what the serving engine, the planner,
# the training telemetry, and the benchmarks all record into unless handed
# an explicit one
REGISTRY = Registry()


def get_registry() -> Registry:
    return REGISTRY


def set_registry(reg: Registry) -> Registry:
    """Swap the process-global registry (tests); returns the previous one."""
    global REGISTRY
    prev, REGISTRY = REGISTRY, reg
    return prev
