"""repro.obs — dependency-free observability: metrics, jit bridge, profiling.

Three small modules, stdlib-only (no prometheus_client, no opentelemetry —
the container bakes in nothing beyond jax, and the hot paths cannot afford
an import that drags a network stack in):

* :mod:`repro.obs.metrics` — thread-safe counters / gauges / fixed-bucket
  histograms with labels, a process-global default registry, ``snapshot()``
  to nested dicts, JSON-lines and Prometheus text exporters, and a
  ``timed()`` context manager;
* :mod:`repro.obs.jax_bridge` — values computed *inside* jit (feasibility
  gap, support size, loss) flow out through ``jax.debug.callback`` into the
  registry, gated OFF by default so the un-instrumented trace is unchanged;
* :mod:`repro.obs.profile` — ``capture(path)`` around ``jax.profiler.trace``
  plus the stage-scope helpers the schedule executors wrap their
  reduce/solve/apply stages in (named scopes land in the captured trace).
"""
from .metrics import (Counter, Gauge, Histogram, Registry,  # noqa: F401
                      get_registry, set_registry, timed)
from . import jax_bridge, metrics, profile  # noqa: F401

REGISTRY = metrics.REGISTRY
