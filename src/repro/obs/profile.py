"""Profiler plumbing: trace capture + the schedule-stage named scopes.

Two consumers:

* launchers and benchmarks wrap a region in :func:`capture` — a thin,
  None-tolerant wrapper over ``jax.profiler.trace`` (pass the launcher's
  ``--profile-dir`` straight through; empty/None disables cleanly);
* the schedule executors (``core/schedule.py`` jnp path,
  ``core/sharded.py`` shard_map body, ``kernels/codegen`` lowering
  boundaries) wrap each ReduceLevel/OuterSolve/ApplyGroup stage in
  :func:`stage_scope` — a ``jax.named_scope`` whose name is derived from
  the :class:`~repro.core.schedule.Schedule` step metadata, so a captured
  trace attributes device time to the stages the paper's Θ(n+m)
  complexity argument is actually about.

Named scopes cost nothing at runtime (they are lowered-metadata only);
:func:`host_span` is the host-side counterpart (``TraceAnnotation``) for
dispatcher/queue work that never enters a trace.
"""

from __future__ import annotations

import contextlib
import os
import pathlib

import jax

# every projection stage scope shares this prefix — what trace tooling (and
# tests/test_obs.py) greps a captured .xplane.pb for
SCOPE_PREFIX = "proj"


def stage_name(step, index: int | None = None) -> str:
    """Scope name for one schedule step (``ReduceLevel``/``OuterSolve``/
    ``ApplyGroup``): ``proj/reduce0_inf``, ``proj/solve_1``,
    ``proj/apply0_inf`` — stable across executors so jnp, shard_map, and
    codegen runs of one design line up in the trace viewer."""
    kind = type(step).__name__
    if kind == "ReduceLevel":
        return f"{SCOPE_PREFIX}/reduce{index}_{step.norm}"
    if kind == "OuterSolve":
        return f"{SCOPE_PREFIX}/solve_{step.norm}"
    if kind == "ApplyGroup":
        return f"{SCOPE_PREFIX}/apply{index}_{step.norm}"
    raise TypeError(f"not a schedule step: {step!r}")


def stage_scope(step, index: int | None = None):
    """``jax.named_scope`` for one schedule step (trace-time metadata only)."""
    return jax.named_scope(stage_name(step, index))


def scope(name: str):
    """A raw ``proj/``-prefixed named scope (codegen lowering boundaries)."""
    return jax.named_scope(f"{SCOPE_PREFIX}/{name}")


def host_span(name: str):
    """Host-side annotation (``jax.profiler.TraceAnnotation``) for work that
    happens outside any traced computation — dispatcher picks, plan builds."""
    return jax.profiler.TraceAnnotation(name)


@contextlib.contextmanager
def capture(path):
    """Capture a profiler trace of the block into ``path``.

    ``path`` falsy (None/"") disables capture — launchers pass their
    ``--profile-dir`` flag through unconditionally. The directory is
    created; afterwards it holds the ``.xplane.pb`` (plus a Perfetto
    ``.trace.json.gz``) that ``jax.profiler`` tooling / TensorBoard read.
    """
    if not path:
        yield None
        return
    path = os.fspath(path)
    pathlib.Path(path).mkdir(parents=True, exist_ok=True)
    with jax.profiler.trace(path):
        yield path


def trace_files(path):
    """The capture artifacts under ``path`` (recursive; files only)."""
    root = pathlib.Path(path)
    return sorted(p for p in root.rglob("*") if p.is_file())
