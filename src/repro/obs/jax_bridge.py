"""Host-callback bridge: metrics computed *inside* jit flow into the registry.

A projected train step knows things worth observing that only exist on the
device — the feasibility gap after projection, the support size of the
projected weights, the loss — but reading them back with ``float(x)`` forces
a device sync on the hot path. This bridge ships them out through
``jax.debug.callback`` instead: the callback is enqueued behind the step's
real work (``ordered=False``) and the host thread folds the value into the
process-global registry whenever it lands.

The bridge is **gated off by default** and the gate is *trace-time static*:
``report(...)`` inside a function traced while the bridge is disabled
lowers to nothing at all — the jitted program is bit-identical to the
un-instrumented one (the ≤2% overhead-off gate in
``benchmarks/obs_overhead.py`` pins exactly this). Enabling the bridge and
re-tracing (new shapes, or an explicit cache clear) is what turns the
telemetry on; the ``REPRO_OBS_BRIDGE=1`` env var enables it from launch.

    from repro.obs import jax_bridge

    jax_bridge.enable()

    @jax.jit
    def step(w):
        x = project(w)
        jax_bridge.report("feasibility_gap", gap(x), kind="gauge")
        return x
"""

from __future__ import annotations

import contextlib
import os
import time
from typing import Dict, Optional

import jax
import numpy as np

from . import metrics

_ENABLED = os.environ.get("REPRO_OBS_BRIDGE", "") == "1"

_HELP = "bridged from inside jit (obs.jax_bridge)"


def enabled() -> bool:
    """Whether ``report()`` emits callbacks for traces made *now*."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


@contextlib.contextmanager
def enabled_scope(on: bool = True):
    """Temporarily flip the gate (tests): traces made inside see ``on``."""
    global _ENABLED
    prev, _ENABLED = _ENABLED, bool(on)
    try:
        yield
    finally:
        _ENABLED = prev


def _record(name: str, kind: str, labels: Optional[Dict[str, str]], value):
    reg = metrics.get_registry()
    v = float(np.asarray(value))
    if kind == "counter":
        fam = reg.counter(name, _HELP, labels=tuple(labels or ()))
    elif kind == "hist":
        fam = reg.histogram(name, _HELP, labels=tuple(labels or ()))
    else:
        fam = reg.gauge(name, _HELP, labels=tuple(labels or ()))
    child = fam.labels(**labels) if labels else fam
    if kind == "counter":
        child.inc(v)
    elif kind == "hist":
        child.observe(v)
    else:
        child.set(v)


def report(name: str, value, *, kind: str = "gauge",
           labels: Optional[Dict[str, str]] = None) -> None:
    """Emit one scalar from traced code into the registry (async, no sync).

    ``kind`` is ``"gauge"`` (set), ``"counter"`` (inc by value), or
    ``"hist"`` (observe). ``labels`` must be static strings (they become
    part of the lowered program). No-op — literally absent from the jitted
    program — when the bridge is disabled at trace time.
    """
    if not _ENABLED:
        return
    if kind not in ("gauge", "counter", "hist"):
        raise ValueError(f"unknown bridge kind {kind!r}")
    labels = dict(labels) if labels else None
    jax.debug.callback(
        lambda v, _name=name, _kind=kind, _labels=labels:
            _record(_name, _kind, _labels, v),
        value)


def mark(name: str, *, labels: Optional[Dict[str, str]] = None) -> None:
    """Drop an *ordered* host-arrival timestamp marker from traced code.

    A ``mark("x_start")`` / ``mark("x_end")`` pair brackets a traced region;
    the host records ``perf_counter()`` when each callback arrives and folds
    the pair's difference into the ``<x>_seconds`` histogram. Because the
    callbacks are ordered they serialize with the surrounding computation —
    on CPU (and in interpret mode) the difference is a faithful stage
    timing; on an accelerator it measures the dispatch stream, which is
    still the ordering the trace viewer shows. Costlier than ``report``
    (ordering forces sequencing): keep it on an ``every``-step cadence.
    No-op when the bridge is disabled at trace time.
    """
    if not _ENABLED:
        return
    if not (name.endswith("_start") or name.endswith("_end")):
        raise ValueError(
            f"mark name must end in _start or _end, got {name!r}")
    labels = dict(labels) if labels else None
    jax.debug.callback(
        lambda _name=name, _labels=labels: _mark_record(_name, _labels),
        ordered=True)


_pending_marks: Dict[str, float] = {}


def _mark_record(name: str, labels: Optional[Dict[str, str]]) -> None:
    now = time.perf_counter()
    stem, _, edge = name.rpartition("_")
    key = stem + "|" + "|".join(
        f"{k}={v}" for k, v in sorted((labels or {}).items()))
    if edge == "start":
        _pending_marks[key] = now
        return
    t0 = _pending_marks.pop(key, None)
    if t0 is None:
        return  # unmatched end (e.g. bridge enabled mid-stream): drop it
    fam = metrics.get_registry().histogram(
        f"{stem}_seconds", _HELP, labels=tuple(labels or ()))
    child = fam.labels(**labels) if labels else fam
    child.observe(now - t0)
