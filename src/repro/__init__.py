"""repro — production-grade JAX framework for multi-level norm-ball projection
(Perez & Barlaud 2024) with structured-sparsity training at pod scale."""

__version__ = "1.0.0"
