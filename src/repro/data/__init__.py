"""repro.data — deterministic shardable pipelines + paper datasets."""
from .pipeline import (  # noqa: F401
    DataConfig, DataPipeline, TokenFileReader, classification_synthetic,
    lung_like,
)
from .activations import (  # noqa: F401
    ActivationReader, HarvestConfig, harvest, read_meta,
)
