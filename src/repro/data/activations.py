"""Activation harvesting: stream per-layer LM activations into disk shards.

The factory's first stage (training/sae_factory.py) runs a configured LM over
the deterministic token stream and captures the residual-stream or MLP-branch
activations of every requested layer (``models.lm.forward(collect=...)``).
Each harvest step appends one shard per layer:

    out_dir/
      meta.json                    — d_model, layers, site, dtype,
                                     rows_per_shard, n_shards, arch, seq_len
      layer03_shard00004.npy       — (rows_per_shard, d_model) array

Shards are plain ``np.save`` files so the reader memory-maps them (no load
copies), mirroring ``TokenFileReader``. ``DataPipeline`` consumes a harvest
directory directly: ``DataConfig(activation_dir=..., activation_layer=...)``
makes ``batch(step)`` yield ``(n_micro, microbatch, d_model)`` float rows with
the same stateless wrap-around indexing as the token path — the step counter
remains the only cursor, so checkpoint-restart semantics carry over unchanged.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HarvestConfig:
    """What to capture and how to lay it out on disk."""
    site: str = "resid"              # "resid" (post-block) | "mlp" (branch out)
    layers: Optional[Sequence[int]] = None   # None -> every layer
    dtype: str = "float32"
    n_steps: int = 4                 # harvest steps (shards per layer)

    def __post_init__(self):
        if self.site not in ("resid", "mlp"):
            raise ValueError(f"unknown harvest site {self.site!r}")


def _shard_name(layer: int, step: int) -> str:
    return f"layer{layer:03d}_shard{step:05d}.npy"


def harvest(params, cfg, pipe, out_dir, *, hcfg: HarvestConfig = None,
            forward=None, impl: str = "naive") -> dict:
    """Run the LM over ``pipe``'s token stream and shard activations to disk.

    ``pipe`` is a ``DataPipeline`` over tokens; each step's
    ``(n_micro, mb, S)`` batch is flattened to ``(B, S)`` and pushed through
    ``forward(collect=site)`` (defaults to ``models.lm.forward``; any forward
    with the same ``collect`` contract works). Activations come back stacked
    ``(L, B, S, D)``; each selected layer's rows are flattened to
    ``(B*S, D)`` and appended as one shard. Returns the manifest dict
    (also written to ``meta.json``).
    """
    from repro.models import lm

    hcfg = hcfg or HarvestConfig()
    fwd = forward or lm.forward
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)

    @jax.jit
    def capture(p, toks):
        _, _, acts = fwd(p, toks, cfg, impl=impl, remat=False,
                         collect=hcfg.site)
        return acts

    layers = None
    rows_per_shard = None
    np_dtype = np.dtype(hcfg.dtype)
    for step in range(hcfg.n_steps):
        toks = np.asarray(pipe.batch(step))
        toks = toks.reshape(-1, toks.shape[-1])          # (B, S)
        acts = np.asarray(capture(params, jnp.asarray(toks)))  # (L, B, S, D)
        if layers is None:
            layers = list(hcfg.layers) if hcfg.layers is not None \
                else list(range(acts.shape[0]))
            bad = [l for l in layers if not 0 <= l < acts.shape[0]]
            if bad:
                raise ValueError(f"layers {bad} out of range for "
                                 f"{acts.shape[0]}-layer model")
            rows_per_shard = acts.shape[1] * acts.shape[2]
        for l in layers:
            rows = acts[l].reshape(rows_per_shard, -1).astype(np_dtype)
            np.save(out / _shard_name(l, step), rows)
    meta = {
        "d_model": int(cfg.d_model), "layers": layers, "site": hcfg.site,
        "dtype": np_dtype.name, "rows_per_shard": int(rows_per_shard),
        "n_shards": int(hcfg.n_steps), "arch": cfg.name,
        "seq_len": int(np.asarray(pipe.batch(0)).shape[-1]),
    }
    (out / "meta.json").write_text(json.dumps(meta, indent=1) + "\n")
    return meta


def read_meta(harvest_dir) -> dict:
    return json.loads((pathlib.Path(harvest_dir) / "meta.json").read_text())


class ActivationReader:
    """Memory-mapped reader over one layer's shards (DataPipeline plug-in).

    Same contract as ``TokenFileReader``: ``batch(step)`` returns
    ``global_batch`` rows, strided by step with stateless wrap-around — the
    step index IS the cursor. Rows come back ``(global_batch, d_model)`` in
    the harvest dtype.
    """

    def __init__(self, harvest_dir, cfg):
        self.cfg = cfg
        self.meta = read_meta(harvest_dir)
        layer = cfg.activation_layer
        if layer not in self.meta["layers"]:
            raise ValueError(f"layer {layer} not harvested; have "
                             f"{self.meta['layers']}")
        root = pathlib.Path(harvest_dir)
        self.shards = [np.load(root / _shard_name(layer, s), mmap_mode="r")
                       for s in range(self.meta["n_shards"])]
        self.rows_per_shard = self.meta["rows_per_shard"]
        self.n_rows = self.rows_per_shard * len(self.shards)
        if cfg.global_batch > self.n_rows:
            raise ValueError(f"global_batch {cfg.global_batch} exceeds "
                             f"harvested rows {self.n_rows}")

    def batch(self, step: int) -> np.ndarray:
        gb = self.cfg.global_batch
        idx = (np.uint64(step) * np.uint64(gb)
               + np.arange(gb, dtype=np.uint64)) % np.uint64(self.n_rows)
        shard = (idx // self.rows_per_shard).astype(np.int64)
        row = (idx % np.uint64(self.rows_per_shard)).astype(np.int64)
        out = np.empty((gb, self.meta["d_model"]),
                       dtype=np.dtype(self.meta["dtype"]))
        for s in np.unique(shard):
            sel = shard == s
            out[sel] = self.shards[s][row[sel]]
        return out
