"""LM serving helpers: batched prefill + single-token decode steps (the
``serve_step`` lowered by the decode_* dry-run cells) and eager greedy
generation, used by examples/serve_lm.py. The projection serving engine —
the async continuous-batching tier — lives in ``serving/engine.py``.

Decode semantics per family:
  dense/moe/vlm : KV (or MLA latent) cache, seq sharded over 'model'
  audio         : decoder self-cache + precomputed cross K/V
  ssm / hybrid  : O(1) recurrent state
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.types import ArchConfig
from repro import models


def make_decode_step(cfg: ArchConfig, api, *, n_groups: int = 1):
    """(params, tokens (B,), cache, pos) -> (next_tokens, logits, cache)."""

    def step(params, tokens, cache, pos):
        kw = {}
        if cfg.family in ("dense", "moe", "vlm"):
            kw["n_groups"] = n_groups
        logits, cache = api.decode_step(params, tokens, cache, pos, cfg, **kw)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return step


def make_prefill(cfg: ArchConfig, api, *, impl="chunked", act_spec=None):
    """Teacher-forced pass returning last-position logits (+cache for LMs)."""

    def prefill(params, tokens):
        kw = {"remat": True, "act_spec": act_spec}
        if cfg.family not in ("ssm", "hybrid"):
            kw["impl"] = impl
        logits, _ = api.forward(params, tokens, cfg, **kw)
        return logits[:, -1]

    return prefill


def generate(params, cfg: ArchConfig, prompt, max_new: int, *,
             n_groups: int = 1, max_len: Optional[int] = None):
    """Eager greedy generation for the examples: prefill by replaying the
    prompt through decode_step (simple + exact), then greedy continue."""
    api = models.get(cfg)
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    cache = api.make_cache(cfg, b, max_len, dtype=jnp.float32)
    step = jax.jit(make_decode_step(cfg, api, n_groups=n_groups),
                   static_argnames=())
    toks = prompt
    nxt = None
    for i in range(s):  # traced pos -> one compile for all steps
        nxt, _, cache = step(params, toks[:, i], cache, jnp.int32(i))
    out = [nxt]
    for j in range(max_new - 1):
        nxt, _, cache = step(params, out[-1], cache, jnp.int32(s + j))
        out.append(nxt)
    return jnp.stack(out, axis=1)
