"""repro.serving — prefill/decode serve steps, batched request engine, and
the plan-batched projection service."""
from .engine import generate, make_decode_step, make_prefill  # noqa: F401
from .projection_service import ProjectionService  # noqa: F401
