"""repro.serving — the continuous-batching projection engine (async
submit/poll, DESIGN.md §5), the legacy flush()-driven projection service,
and LM prefill/decode serve steps."""
from .engine import (DeadlineExceededError, ProjectionEngine,  # noqa: F401
                     QueueFullError, ServingError, Ticket,
                     UnknownTicketError)
from .lm import generate, make_decode_step, make_prefill  # noqa: F401
from .projection_service import ProjectionService  # noqa: F401
