"""repro.serving — prefill/decode serve steps + batched request engine."""
from .engine import generate, make_decode_step, make_prefill  # noqa: F401
