"""Continuous-batching projection engine: async submit/poll with latency SLOs.

The engine is the production serving tier over the projection planner
(DESIGN.md §5). It replaces the bucket-and-wait flow of
:class:`~repro.serving.projection_service.ProjectionService` — where a
request waits until its group is explicitly ``flush()``-ed — with
**continuous batching**: a background dispatcher pops *every* request
pending for one plan key the moment that key's plan is ready, so a request
joins the next in-flight dispatch for its key instead of waiting for a
bucket to fill or a caller to flush.

Four mechanisms make the latency profile (DESIGN.md §5 derives the model):

* **continuous batching** — one dispatch serves everything that arrived for
  a key since its last dispatch (popped group capped at ``max_batch``,
  padded to the next power of two so varying traffic re-traces the batch
  executable only O(log max_batch) times);
* **buffer donation** — the engine takes ownership of every submitted
  payload: each dispatch is one fused jitted call (stack → project →
  unstack) that donates the request buffers at its boundary, so projections
  run in place and the stacked bucket never exists outside the executable;
* **plan-cache warm pool** — plans build on a thread pool, and the
  dispatcher skips keys whose plan is still building: a cold shape never
  stalls the hot path. ``prewarm()`` schedules builds ahead of traffic;
* **admission control** — the queue is bounded (``max_pending``); overload
  is shed at ``submit()`` with a typed :class:`QueueFullError`, and
  per-request deadlines double as dispatch hints (the dispatcher serves the
  earliest-deadline key first; requests past their deadline complete with
  :class:`DeadlineExceededError` instead of burning compute).

Mesh-sharded submissions keep their own plan key and execute per request
through the sharded schedule executor — they are never gather-stacked with
single-device traffic of the same shape (DESIGN.md §5).

Typical use (see docs/serving.md for a runnable tour)::

    with ProjectionEngine() as eng:
        t1 = eng.submit(w1, [("inf", 1), ("1", 1)], radius=1.0)
        t2 = eng.submit(w2, [("inf", 1), ("1", 1)], radius=2.0)  # joins t1's dispatch
        x1 = eng.result(t1, timeout=5.0)
        x2 = eng.result(t2, timeout=5.0)

Failure semantics: a dispatch that raises re-queues its group (at the front,
order preserved) and retries up to ``max_attempts`` times; after that every
ticket in the group completes exceptionally. ``result()`` re-raises the
stored error; an unknown, already-claimed, or discarded ticket raises
:class:`UnknownTicketError`.
"""

from __future__ import annotations

import contextlib
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import multilevel
from repro.core import plan as planmod
from repro.obs import metrics as obs_metrics
from repro.obs.metrics import timed

# THE clock for everything time-shaped in this module — deadlines, queue
# ages, latency accounting. A single *monotonic* source: wall-clock
# (time.time) jumps — NTP steps, suspend/resume — must never expire a
# deadline or corrupt a latency histogram (regression-pinned in
# tests/test_serving.py). Tests monkeypatch this one name to fake time.
_now = time.monotonic

# (shape, dtype name, canonical levels, canonical method, sharding key) —
# same grouping rule as ProjectionService: requests share a dispatch iff
# they share a planner executable
GroupKey = Tuple[Tuple[int, ...], str, Tuple[Tuple[str, int], ...], str,
                 object]

# batch-size distribution buckets: the pow-2 dispatch buckets themselves
_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def _key_label(key: GroupKey) -> str:
    """Compact per-plan-key metric label: ``6x10/float32/inf1-11/sort``."""
    shape, dtype, levels, method, shard = key
    lv = "-".join(f"{q}{k}" for q, k in levels)
    base = f"{'x'.join(map(str, shape))}/{dtype}/{lv}/{method}"
    return base + "/sharded" if shard is not None else base


class ServingError(RuntimeError):
    """Base class for engine failures surfaced through tickets."""


class QueueFullError(ServingError):
    """Admission control: the bounded queue is full — shed load upstream."""


class DeadlineExceededError(ServingError):
    """The request's deadline passed before its dispatch executed."""


class UnknownTicketError(ServingError, KeyError):
    """The ticket is not pending here: foreign, already claimed, or
    discarded."""


def _bucket(n: int) -> int:
    """Next power of two ≥ n (bucketed padding: O(log max_batch) traces)."""
    return 1 << (n - 1).bit_length()


class Ticket:
    """Handle for one submitted projection. Opaque: hand it back to
    :meth:`ProjectionEngine.poll` / :meth:`ProjectionEngine.result`."""

    __slots__ = ("id", "key", "_engine", "_event", "_state", "_value",
                 "_error")

    def __init__(self, tid: int, key: GroupKey, engine: "ProjectionEngine"):
        self.id = tid
        self.key = key
        self._engine = engine
        self._event = threading.Event()
        self._state = "pending"          # -> done | failed -> claimed
        self._value: Optional[jax.Array] = None
        self._error: Optional[BaseException] = None

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Ticket(id={self.id}, state={self._state})"


class _Request:
    __slots__ = ("ticket", "y", "radius", "deadline", "attempts", "enqueued")

    def __init__(self, ticket: Ticket, y, radius, deadline: Optional[float]):
        self.ticket = ticket
        self.y = y
        self.radius = radius
        self.deadline = deadline          # absolute _now() time, or None
        self.attempts = 0
        self.enqueued = _now()


class EngineStats(dict):
    """The engine's operational counters — a plain dict (back-compat:
    ``eng.stats["dispatches"]``) that is ALSO callable: ``eng.stats()``
    returns the full structured snapshot (counters, queue state, per-key
    latency summaries, planner cache info). See
    :meth:`ProjectionEngine.stats_snapshot`."""

    def __init__(self, engine: "ProjectionEngine", *args, **kw):
        super().__init__(*args, **kw)
        self._engine = engine

    def __call__(self) -> dict:
        return self._engine.stats_snapshot()


class _EngineMetrics:
    """The engine's registry handles, built once per engine.

    All series live in the process-global obs registry (labelled by plan
    key where it matters), so one scrape sees every engine in the process.
    ``instrument=False`` engines skip this object entirely — the bare hot
    path performs zero registry operations (the ≤2% overhead-off gate in
    benchmarks/obs_overhead.py measures exactly that configuration).
    """

    def __init__(self):
        reg = obs_metrics.get_registry()
        self.queue_depth = reg.gauge(
            "serving_queue_depth", "queued (undispatched) requests")
        self.inflight = reg.gauge(
            "serving_inflight_requests", "popped but not yet completed")
        self.events = reg.counter(
            "serving_events_total", "engine lifecycle events",
            labels=("event",))
        self.queue_s = reg.histogram(
            "serving_queue_seconds", "submit -> dispatch-pop wait",
            labels=("key",))
        self.e2e_s = reg.histogram(
            "serving_e2e_seconds", "submit -> completion latency",
            labels=("key",))
        self.dispatch_s = reg.histogram(
            "serving_dispatch_seconds", "one group's execute time",
            labels=("key",))
        self.batch_size = reg.histogram(
            "serving_batch_size", "requests per dispatch",
            buckets=_BATCH_BUCKETS)
        self.plan_build_s = reg.histogram(
            "serving_plan_build_seconds", "plan build on the warm pool")
        self.warm_s = reg.histogram(
            "serving_warm_seconds", "warm-bucket pre-trace on the warm pool")
        # hot-path handle caches: resolving a labelled child costs a label
        # check + tuple build + lock per call — done ONCE per key/event
        # here, so the per-request cost is a dict hit (GIL-atomic)
        self._by_key: Dict[GroupKey, tuple] = {}
        self.ev = {name: self.events.labels(event=name)
                   for name in ("submitted", "rejected", "expired",
                                "requeue", "failure", "dispatch",
                                "completed", "failed", "discarded")}

    def for_key(self, key: GroupKey) -> tuple:
        """(queue_s, e2e_s, dispatch_s) histogram children for one key."""
        h = self._by_key.get(key)
        if h is None:
            lbl = _key_label(key)
            h = (self.queue_s.labels(key=lbl), self.e2e_s.labels(key=lbl),
                 self.dispatch_s.labels(key=lbl))
            self._by_key[key] = h
        return h


class ProjectionEngine:
    """Async continuous-batching projection server over the planner.

    Parameters
    ----------
    method:       default backend request for every submit (``"auto"``
                  autotunes per workload); per-submit ``method=`` overrides.
    max_batch:    cap on one dispatch's group size (the pow-2 padding bucket
                  never exceeds it).
    max_pending:  admission-control bound on queued (undispatched) requests;
                  ``submit()`` past it raises :class:`QueueFullError`.
    donate:       donate payload buffers to the executable (in-place
                  projection). The engine takes ownership of submitted
                  buffers: a singleton dispatch *consumes* the caller's
                  array (donation invariant, DESIGN.md §5).
    max_attempts: dispatch attempts per request before its group's failure
                  is surfaced through the tickets.
    warm_workers: threads in the plan warm pool.
    warm_buckets: pow-2 bucket sizes per key to pre-trace on the warm pool
                  (e.g. 3 traces buckets 1, 2, 4). Tracing a bucket size at
                  build time moves its one-time trace/compile cost off the
                  first dispatch that reaches it — under open-loop traffic
                  one mid-replay compile delays the whole backlog. 0 (the
                  default) builds plans only.
    interpret:    run Pallas-backed plans in interpreter mode (tests/CPU).
    instrument:   record queue/latency/batch/deadline metrics into the
                  process-global obs registry (``repro.obs``). ``False`` is
                  the bare hot path — zero registry operations per request
                  (the counter dict ``stats`` is always maintained either
                  way; only histograms/gauges/labelled series are gated).
    start:        launch the background dispatcher thread. With
                  ``start=False`` the engine is synchronous: nothing runs
                  until :meth:`drain` dispatches inline (deterministic mode
                  for tests and benchmarks).
    """

    def __init__(self, *, method: str = planmod.AUTO, max_batch: int = 64,
                 max_pending: int = 1024, donate: bool = True,
                 max_attempts: int = 2, warm_workers: int = 2,
                 warm_buckets: int = 0, interpret: bool = False,
                 instrument: bool = True, start: bool = True):
        if max_batch < 1 or max_pending < 1 or max_attempts < 1:
            raise ValueError(
                "max_batch, max_pending, max_attempts must be >= 1")
        self.warm_buckets = int(warm_buckets)
        self.default_method = method
        self.max_batch = int(max_batch)
        self.max_pending = int(max_pending)
        self.donate = bool(donate)
        self.max_attempts = int(max_attempts)
        self.interpret = bool(interpret)
        self._cv = threading.Condition()
        self._queues: Dict[GroupKey, List[_Request]] = {}
        self._plans: Dict[GroupKey, Future] = {}
        self._fused: Dict[Tuple[GroupKey, int], object] = {}
        self._pending_count = 0
        self._inflight = 0
        self._inflight_reqs = 0
        self._next_ticket = 0
        self._stopping = False
        self.stats = EngineStats(
            self, {"submitted": 0, "dispatches": 0, "batched_requests": 0,
                   "rejected": 0, "expired": 0, "requeues": 0,
                   "failures": 0, "max_group": 0, "completed": 0,
                   "failed": 0, "discarded": 0})
        self._metrics = _EngineMetrics() if instrument else None
        self._warm = ThreadPoolExecutor(max_workers=int(warm_workers),
                                        thread_name_prefix="plan-warm")
        self._thread: Optional[threading.Thread] = None
        if start:
            self._thread = threading.Thread(target=self._loop,
                                            name="projection-dispatch",
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- submit

    def submit(self, y, levels, radius=1.0, *, method: Optional[str] = None,
               deadline: Optional[float] = None) -> Ticket:
        """Queue one projection; returns a :class:`Ticket`.

        ``deadline`` is seconds from now: a request still queued past it
        completes with :class:`DeadlineExceededError` instead of executing,
        and pending deadlines prioritise which key dispatches next.

        Raises :class:`QueueFullError` when ``max_pending`` requests are
        already queued, and ``ValueError`` for an invalid design/backend —
        bad requests are rejected here, where the caller can handle it.
        """
        with self._cv:
            if self._stopping:
                raise ServingError("engine is stopped")
        y = jnp.asarray(y)
        levels = planmod.canonical_levels(levels)
        multilevel._check_levels(y.shape, levels)
        # committed mesh-sharded tensors get their own plan key: they run
        # through the sharded schedule executor per request, never
        # gather-stacked with single-device traffic of the same shape
        sharding = getattr(y, "sharding", None)
        if not isinstance(sharding, jax.sharding.NamedSharding):
            sharding = None
        shard_key = planmod.canonical_sharding(sharding, y.ndim)
        requested = self.default_method if method is None else method
        requested = planmod.validate_backend(
            y.shape, y.dtype, levels, requested, sharding=shard_key,
            interpret=self.interpret,
            radius_kind="scalar" if shard_key is not None else "batch")
        radius = jnp.asarray(radius, y.dtype)
        if radius.ndim != 0:
            raise ValueError(
                f"radius must be a scalar (one per request), got shape "
                f"{radius.shape}")
        key: GroupKey = (y.shape, y.dtype.name, levels, requested, shard_key)
        abs_deadline = None if deadline is None else _now() + float(deadline)
        m = self._metrics
        with self._cv:
            if self._stopping:
                raise ServingError("engine is stopped")
            if self._pending_count >= self.max_pending:
                self.stats["rejected"] += 1
                if m:
                    m.ev["rejected"].inc()
                raise QueueFullError(
                    f"{self._pending_count} requests queued "
                    f"(max_pending={self.max_pending})")
            ticket = Ticket(self._next_ticket, key, self)
            self._next_ticket += 1
            self._queues.setdefault(key, []).append(
                _Request(ticket, y, radius, abs_deadline))
            self._pending_count += 1
            self.stats["submitted"] += 1
            if m:
                m.ev["submitted"].inc()
                m.queue_depth.set(self._pending_count)
            self._ensure_plan_locked(key)
            self._cv.notify_all()
        return ticket

    def prewarm(self, shape, dtype, levels, *, method: Optional[str] = None,
                sharding=None) -> None:
        """Schedule the plan build for a workload ahead of traffic, on the
        warm pool. Returns immediately; the first submit for this key then
        dispatches without a cold-build stall."""
        shape = tuple(int(s) for s in shape)
        levels = planmod.canonical_levels(levels)
        multilevel._check_levels(shape, levels)
        shard_key = planmod.canonical_sharding(sharding, len(shape))
        requested = self.default_method if method is None else method
        requested = planmod.validate_backend(
            shape, dtype, levels, requested, sharding=shard_key,
            interpret=self.interpret,
            radius_kind="scalar" if shard_key is not None else "batch")
        key: GroupKey = (shape, jnp.dtype(dtype).name, levels, requested,
                         shard_key)
        with self._cv:
            self._ensure_plan_locked(key)

    def wait_warm(self, timeout: Optional[float] = None) -> None:
        """Block until every scheduled plan build (and its warm-bucket
        traces) has finished. Re-raises the first build failure."""
        with self._cv:
            futs = list(self._plans.values())
        for fut in futs:
            fut.result(timeout)

    # --------------------------------------------------------- plan cache

    def _ensure_plan_locked(self, key: GroupKey) -> None:
        if key not in self._plans:
            fut = self._warm.submit(self._build_plans, key)
            fut.add_done_callback(self._on_plan_ready)
            self._plans[key] = fut

    def _on_plan_ready(self, _fut: Future) -> None:
        with self._cv:
            self._cv.notify_all()

    def _build_plans(self, key: GroupKey) -> Dict[str, planmod.ProjectionPlan]:
        """Build every plan flavour one key dispatches through (runs on the
        warm pool, so a cold key never stalls the dispatcher)."""
        if self._metrics:
            with timed(self._metrics.plan_build_s):
                return self._build_plans_inner(key)
        return self._build_plans_inner(key)

    def _build_plans_inner(self, key: GroupKey
                           ) -> Dict[str, planmod.ProjectionPlan]:
        shape, dtype, levels, method, shard_key = key
        if shard_key is not None:
            # sharded: per-request scalar plan, no donation (the sharded
            # executor manages its own per-shard buffers)
            return {"scalar": planmod.make_plan(shape, dtype, levels,
                                                method=method,
                                                sharding=shard_key)}
        # the batch plan itself is NOT donated: the fused dispatch wrapper
        # (see _fused_dispatch) donates the per-request payloads at its own
        # boundary and the stacked bucket is internal to the jit
        plans = {"batch": planmod.make_plan(
            shape, dtype, levels, radius_kind="batch", method=method,
            interpret=self.interpret)}
        if not planmod.is_batch_native(method):
            # singleton fast path: donate the caller's own buffer (true
            # in-place projection, zero copies). Batch-native backends take
            # stacked buckets only, so they route size-1 groups through the
            # batch plan instead.
            plans["scalar"] = planmod.make_plan(
                shape, dtype, levels, method=method,
                interpret=self.interpret, donate=self.donate)
        self._warm_dispatch_paths(key, plans)
        return plans

    def _warm_dispatch_paths(self, key: GroupKey, plans) -> None:
        """Trace the first ``warm_buckets`` pow-2 dispatch paths (stack +
        executable + unstack) with dummy payloads, still on the warm pool.
        Best-effort: a failure here resurfaces at the real dispatch, where
        the retry/typed-error machinery handles it."""
        shape, dtype_name, _levels, _method, shard_key = key
        if shard_key is not None or self.warm_buckets <= 0:
            return
        dtype = jnp.dtype(dtype_name)
        dummy = lambda: _Request(None, jnp.zeros(shape, dtype),
                                 jnp.asarray(0.5, dtype), None)
        ctx = timed(self._metrics.warm_s) if self._metrics \
            else contextlib.nullcontext()
        try:
            with ctx:
                if "scalar" in plans:
                    r = dummy()
                    jax.block_until_ready(plans["scalar"](r.y, r.radius))
                b, done = 1, 0
                while b <= self.max_batch and done < self.warm_buckets:
                    jax.block_until_ready(self._run_group(
                        key, plans, [dummy() for _ in range(b)]))
                    b, done = b * 2, done + 1
        except Exception:
            pass

    # --------------------------------------------------------- dispatcher

    def _loop(self) -> None:
        while True:
            with self._cv:
                if self._stopping and self._pending_count == 0:
                    break
            self._dispatch_once()

    def _dispatch_once(self, wait_s: float = 0.02) -> bool:
        """Pop and execute one group; returns whether anything ran."""
        m = self._metrics
        with self._cv:
            key = self._select_key_locked()
            if key is None:
                self._cv.wait(wait_s)
                return False
            reqs = self._queues.pop(key)
            take, rest = reqs[:self.max_batch], reqs[self.max_batch:]
            if rest:
                self._queues[key] = rest
            self._pending_count -= len(take)
            self._inflight += 1
            self._inflight_reqs += len(take)
            if m:
                m.queue_depth.set(self._pending_count)
                m.inflight.set(self._inflight_reqs)
        if m:
            popped, (queue_h, _, _) = _now(), m.for_key(key)
            for r in take:
                queue_h.observe(popped - r.enqueued)
        try:
            self._execute(key, take)
        finally:
            with self._cv:
                self._inflight -= 1
                self._inflight_reqs -= len(take)
                if m:
                    m.inflight.set(self._inflight_reqs)
                self._cv.notify_all()
        return True

    def _select_key_locked(self) -> Optional[GroupKey]:
        """Earliest-deadline dispatchable key (deadline hints), FIFO on the
        longest-waiting head request among deadline-free keys — a hot key
        cannot starve the others. Keys whose plan is still building are
        skipped — cold never stalls hot."""
        best, best_pri = None, (float("inf"), float("inf"))
        for key, q in self._queues.items():
            if not q:
                continue
            fut = self._plans.get(key)
            if fut is None:
                self._ensure_plan_locked(key)
                continue
            if not fut.done():
                continue
            dl = min((r.deadline for r in q if r.deadline is not None),
                     default=float("inf"))
            pri = (dl, q[0].enqueued)
            if best is None or pri < best_pri:
                best, best_pri = key, pri
        return best

    def _execute(self, key: GroupKey, reqs: List[_Request]) -> None:
        m = self._metrics
        e2e_h = dispatch_h = None
        if m:
            _, e2e_h, dispatch_h = m.for_key(key)
        try:
            plans = self._plans[key].result()
        except Exception as exc:
            with self._cv:
                # drop the failed build so a later submit retries it
                self._plans.pop(key, None)
            err = ServingError(f"plan build failed for {key[:4]}: {exc!r}")
            err.__cause__ = exc
            for r in reqs:
                self._fail(r.ticket, err)
            return
        now = _now()
        live = []
        for r in reqs:
            if r.ticket._state != "pending":      # discarded before dispatch
                continue
            if r.deadline is not None and now > r.deadline:
                self.stats["expired"] += 1
                if m:
                    m.ev["expired"].inc()
                self._fail(r.ticket, DeadlineExceededError(
                    f"ticket {r.ticket.id} expired "
                    f"{now - r.deadline:.3f}s before dispatch"))
                continue
            live.append(r)
        if not live:
            return
        try:
            t0 = _now()
            outs = self._run_group(key, plans, live)
            if m:
                dispatch_h.observe(_now() - t0)
        except Exception as exc:
            for r in live:
                r.attempts += 1
            retry = [r for r in live if r.attempts < self.max_attempts]
            spent = [r for r in live if r.attempts >= self.max_attempts]
            for r in spent:
                self.stats["failures"] += 1
                if m:
                    m.ev["failure"].inc()
                err = ServingError(
                    f"dispatch failed after {r.attempts} attempt(s): {exc!r}")
                err.__cause__ = exc
                self._fail(r.ticket, err)
            if retry:
                self.stats["requeues"] += 1
                if m:
                    m.ev["requeue"].inc()
                with self._cv:
                    # re-queue at the front, order preserved
                    self._queues.setdefault(key, [])[0:0] = retry
                    self._pending_count += len(retry)
                    self._cv.notify_all()
            return
        self.stats["dispatches"] += 1
        self.stats["max_group"] = max(self.stats["max_group"], len(live))
        if len(live) > 1:
            self.stats["batched_requests"] += len(live)
        if m:
            m.ev["dispatch"].inc()
            m.batch_size.observe(len(live))
        done = _now()
        for r, out in zip(live, outs):
            self._complete(r.ticket, out)
            if m:
                e2e_h.observe(done - r.enqueued)

    def _fused_dispatch(self, key: GroupKey, plans, b: int):
        """One jitted executable per (key, bucket): stack → project →
        unstack fused into a single dispatch, each request's payload
        donated individually. Without the fusion every dispatch pays
        O(bucket) op-by-op stack/slice calls — which is exactly the
        per-request overhead continuous batching exists to amortize."""
        fn = self._fused.get((key, b))
        if fn is None:
            batch_plan = plans["batch"]

            def dispatch(*args):               # b payloads then b radii
                ys = jnp.stack(args[:b])
                radii = jnp.stack(args[b:])
                out = batch_plan(ys, radii)
                return tuple(out[i] for i in range(b))

            donate = tuple(range(b)) if self.donate else ()
            fn = jax.jit(dispatch, donate_argnums=donate)
            self._fused[(key, b)] = fn
        return fn

    def _run_group(self, key: GroupKey, plans, live) -> List[jax.Array]:
        """The raw compute for one popped group (the retry boundary)."""
        shape, dtype_name, _levels, _method, shard_key = key
        if shard_key is not None:
            p = plans["scalar"]
            return [p(r.y, r.radius) for r in live]
        if len(live) == 1 and "scalar" in plans:
            r = live[0]
            return [plans["scalar"](r.y, r.radius)]
        b = min(_bucket(len(live)), self.max_batch)
        pad = b - len(live)
        dtype = jnp.dtype(dtype_name)
        # pad slots get fresh zero buffers — donation forbids handing the
        # executable the same buffer twice
        args = ([r.y for r in live]
                + [jnp.zeros(shape, dtype) for _ in range(pad)]
                + [r.radius for r in live]
                + [jnp.zeros((), dtype) for _ in range(pad)])
        out = self._fused_dispatch(key, plans, b)(*args)
        return list(out[: len(live)])

    # --------------------------------------------------------- completion

    def _complete(self, ticket: Ticket, value) -> None:
        with self._cv:
            if ticket._state != "pending":        # discarded mid-dispatch
                return
            ticket._state = "done"
            ticket._value = value
            self.stats["completed"] += 1
        if self._metrics:
            self._metrics.ev["completed"].inc()
        ticket._event.set()

    def _fail(self, ticket: Ticket, error: BaseException) -> None:
        with self._cv:
            if ticket._state != "pending":
                return
            ticket._state = "failed"
            ticket._error = error
            self.stats["failed"] += 1
        if self._metrics:
            self._metrics.ev["failed"].inc()
        ticket._event.set()

    # ------------------------------------------------------------ results

    def poll(self, ticket: Ticket) -> bool:
        """True once the ticket completed (result ready or failed)."""
        self._check_ticket(ticket)
        return ticket._event.is_set()

    def result(self, ticket: Ticket, timeout: Optional[float] = None):
        """Projected tensor for a completed ticket — single read (the value
        is released on return). Blocks up to ``timeout`` seconds
        (``TimeoutError`` past it); re-raises the dispatch error for a
        failed ticket; :class:`UnknownTicketError` for a foreign, claimed,
        or discarded ticket."""
        self._check_ticket(ticket)
        if self._thread is None and not ticket._event.is_set():
            self.drain()                   # synchronous mode: dispatch inline
        if not ticket._event.wait(timeout):
            raise TimeoutError(
                f"ticket {ticket.id} incomplete after {timeout}s")
        with self._cv:
            state = ticket._state
            if state == "done":
                ticket._state = "claimed"
                value, ticket._value = ticket._value, None
                return value
            if state == "failed":
                ticket._state = "claimed"
                error, ticket._error = ticket._error, None
            else:
                error = UnknownTicketError(
                    f"ticket {ticket.id} already {state}")
        raise error

    def discard(self, ticket: Ticket) -> None:
        """Drop a ticket that will never be claimed (no-op if already
        claimed). A discarded pending request is skipped at dispatch; a
        discarded completed result is released immediately."""
        self._check_ticket(ticket)
        with self._cv:
            if ticket._state == "claimed":
                return
            if ticket._state == "pending":
                # terminal accounting: a request discarded before its
                # dispatch is neither completed nor failed. If it is still
                # queued it leaves the queue NOW (so queued+discarded never
                # double-count it and its slot frees immediately); a request
                # already popped into a dispatch is skipped at completion.
                q = self._queues.get(ticket.key)
                if q is not None:
                    for i, r in enumerate(q):
                        if r.ticket is ticket:
                            del q[i]
                            if not q:
                                del self._queues[ticket.key]
                            self._pending_count -= 1
                            break
                self.stats["discarded"] += 1
                if self._metrics:
                    self._metrics.ev["discarded"].inc()
                    self._metrics.queue_depth.set(self._pending_count)
            ticket._state = "discarded"
            ticket._value = None
            ticket._error = None
        ticket._event.set()

    def _check_ticket(self, ticket) -> None:
        if not isinstance(ticket, Ticket) or ticket._engine is not self:
            raise UnknownTicketError(
                f"not a ticket of this engine: {ticket!r}")

    # ---------------------------------------------------------- lifecycle

    def pending(self) -> int:
        """Queued (undispatched) requests."""
        with self._cv:
            return self._pending_count

    # ------------------------------------------------------- observability

    def stats_snapshot(self) -> dict:
        """Structured operational snapshot (what ``eng.stats()`` returns).

        Counters plus live queue state plus — on instrumented engines —
        per-plan-key latency summaries (p50/p99 seconds, bucket-estimated)
        and the planner's cache counters. Accounting invariant (pinned in
        tests/test_serving.py)::

            completed + failed + discarded + queued + inflight == submitted
        """
        with self._cv:
            snap: dict = dict(self.stats)
            snap["queued"] = self._pending_count
            snap["inflight"] = self._inflight_reqs
        m = self._metrics
        if m is not None:
            lat = {}
            for fam, field in ((m.queue_s, "queue"), (m.e2e_s, "e2e")):
                for child in fam.children():
                    key = child.labelvalues[0]
                    d = lat.setdefault(key, {})
                    d[f"{field}_count"] = child.count
                    d[f"{field}_p50_s"] = child.quantile(0.5)
                    d[f"{field}_p99_s"] = child.quantile(0.99)
            snap["latency"] = lat
            snap["batch_p50"] = m.batch_size.quantile(0.5)
        snap["plan_cache"] = planmod.cache_info()
        return snap

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed. With
        ``start=False`` this IS the dispatcher: groups execute inline, on
        this thread, until the queue is empty."""
        deadline = None if timeout is None else _now() + timeout
        if self._thread is None:
            while True:
                with self._cv:
                    if not self._pending_count and not self._inflight:
                        return
                if deadline is not None and _now() > deadline:
                    raise TimeoutError("drain timed out")
                self._dispatch_once(wait_s=0.005)
        with self._cv:
            while self._pending_count or self._inflight:
                left = None if deadline is None else deadline - _now()
                if left is not None and left <= 0:
                    raise TimeoutError("drain timed out")
                self._cv.wait(left if left is not None else 0.1)

    def stop(self, drain: bool = True) -> None:
        """Shut the engine down. ``drain=True`` (default) finishes queued
        work first; ``drain=False`` fails still-queued tickets with
        :class:`ServingError`. Idempotent; ``submit()`` raises afterwards."""
        with self._cv:
            self._stopping = True
            if not drain:
                for q in self._queues.values():
                    for r in q:
                        self._fail(r.ticket, ServingError("engine stopped"))
                self._queues.clear()
                self._pending_count = 0
            self._cv.notify_all()
        if self._thread is not None:
            if drain:
                self.drain()
            self._thread.join(timeout=10.0)
            self._thread = None
        elif drain:
            self.drain()
        self._warm.shutdown(wait=True)

    def __enter__(self) -> "ProjectionEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc[0] is None)

    # -------------------------------------------------------- convenience

    def project(self, y, levels, radius=1.0, *,
                method: Optional[str] = None):
        """submit + result in one call (single-request convenience)."""
        return self.result(self.submit(y, levels, radius, method=method))
