"""Synchronous flush()-driven projection batching (the legacy serving flow).

A request is one tensor + norm design + radius. The service groups pending
requests whose *plan key* matches — same shape, dtype, canonical levels, and
backend — stacks each group along a fresh leading axis, and executes it with
ONE planner batch executable (``radius_kind="batch"``, per-request radii).
Heterogeneous traffic therefore costs one dispatch per distinct workload
shape instead of one per request, and every dispatch reuses the planner's
cached, autotuned executable (DESIGN.md §2). Group batches are padded to the
next power of two before stacking, so varying traffic re-traces the batch
executable only O(log max-group) times, not once per distinct group size.

**Deprecated for serving**: nothing executes until a caller invokes
``flush()``, so under live traffic every request waits for its bucket — the
bucket-and-wait latency profile DESIGN.md §5 analyses. New code should use
:class:`repro.serving.engine.ProjectionEngine`: the same plan-key grouping,
but with continuous batching (a request joins the next in-flight dispatch),
buffer donation, a plan warm pool, and admission control. This class stays
as the simple synchronous building block — no threads, explicit flush — and
as the measured baseline of ``benchmarks/run.py --only serving``.

Typical use (see docs/api.md for a runnable version):

    svc = ProjectionService()                       # method="auto"
    t1 = svc.submit(w1, [("inf", 1), ("1", 1)], radius=1.0)
    t2 = svc.submit(w2, [("inf", 1), ("1", 1)], radius=2.0)   # same shape: batched
    t3 = svc.submit(w3, [("1", 1)], radius=1.0)                # own group
    svc.flush()
    x1 = svc.result(t1)

Single-shot convenience: ``svc.project(y, levels, radius)``.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import multilevel
from repro.core import plan as planmod

# (shape, dtype name, canonical levels, requested method, sharding key)
GroupKey = Tuple[Tuple[int, ...], str, Tuple[Tuple[str, int], ...], str,
                 object]


def _bucket(n: int) -> int:
    """Next power of two ≥ n — group batches are padded up to a bucket size so
    the vmap'd executable re-traces O(log max-batch) times, not once per
    distinct group size."""
    return 1 << (n - 1).bit_length()


class ProjectionService:
    """Batches projection requests by plan key and executes them vmap'd.

    ``method`` is the default backend request for every submit (``"auto"``
    autotunes per workload); a per-request ``method=`` overrides it — requests
    with different backends never share a batch.
    """

    def __init__(self, *, method: str = planmod.AUTO):
        self.default_method = method
        self._pending: Dict[GroupKey, List[Tuple[int, jax.Array, jax.Array]]] = {}
        self._results: Dict[int, jax.Array] = {}
        self._next_ticket = 0
        self.stats = {"submitted": 0, "executed_batches": 0,
                      "batched_requests": 0, "flushes": 0}

    def submit(self, y, levels, radius=1.0, *, method: str | None = None) -> int:
        """Queue one projection; returns a ticket for :meth:`result`."""
        y = jnp.asarray(y)
        levels = planmod.canonical_levels(levels)
        # reject bad requests HERE, where the caller can handle it — a raise
        # inside flush() would abort a whole batch for one bad ticket
        multilevel._check_levels(y.shape, levels)
        # committed mesh-sharded tensors get their own plan key: they execute
        # through the sharded schedule executor, never gather-stacked with
        # single-device traffic of the same shape
        sharding = getattr(y, "sharding", None)
        if not isinstance(sharding, jax.sharding.NamedSharding):
            sharding = None
        shard_key = planmod.canonical_sharding(sharding, y.ndim)
        requested = self.default_method if method is None else method
        requested = planmod.validate_backend(y.shape, y.dtype, levels,
                                             requested, sharding=shard_key)
        radius = jnp.asarray(radius, y.dtype)
        if radius.ndim != 0:
            raise ValueError(
                f"radius must be a scalar (one per request), got shape "
                f"{radius.shape}")
        key: GroupKey = (y.shape, y.dtype.name, levels, requested, shard_key)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.setdefault(key, []).append((ticket, y, radius))
        self.stats["submitted"] += 1
        return ticket

    def pending(self) -> int:
        """Number of queued (unflushed) requests."""
        return sum(len(v) for v in self._pending.values())

    def flush(self) -> None:
        """Execute every pending group (one vmap'd dispatch per group;
        sharded groups run the mesh plan per request — stacking them would
        gather the shards, defeating the sharded executor)."""
        for key in list(self._pending):
            (shape, dtype, levels, method, shard_key), reqs = \
                key, self._pending.pop(key)
            try:
                if shard_key is not None:
                    # per-request dispatch, so these do NOT count into
                    # batched_requests (= requests that shared one vmap)
                    p = planmod.make_plan(shape, dtype, levels, method=method,
                                          sharding=shard_key)
                    for ticket, y, radius in reqs:
                        self._results[ticket] = p(y, radius)
                elif len(reqs) == 1:
                    ticket, y, radius = reqs[0]
                    p = planmod.make_plan(shape, dtype, levels, method=method)
                    self._results[ticket] = p(y, radius)
                else:
                    p = planmod.make_plan(shape, dtype, levels,
                                          radius_kind="batch", method=method)
                    pad = _bucket(len(reqs)) - len(reqs)
                    ys = jnp.stack([y for _, y, _ in reqs]
                                   + [reqs[-1][1]] * pad)
                    radii = jnp.stack([r for _, _, r in reqs]
                                      + [reqs[-1][2]] * pad)
                    out = p(ys, radii)
                    for i, (ticket, _, _) in enumerate(reqs):
                        self._results[ticket] = out[i]
                    self.stats["batched_requests"] += len(reqs)
            except Exception:
                # keep the failed group queued (its tickets stay retryable);
                # groups already executed this flush stay executed
                self._pending[key] = reqs
                raise
            self.stats["executed_batches"] += 1
        self.stats["flushes"] += 1

    def result(self, ticket: int) -> jax.Array:
        """Projected tensor for a flushed ticket — single read: the result is
        removed on return. KeyError for an unknown, unflushed, or
        already-claimed ticket."""
        return self._results.pop(ticket)

    def discard(self, ticket: int) -> None:
        """Drop a flushed result that will never be claimed (no-op if absent).

        Long-running callers should discard abandoned tickets (e.g. client
        timeouts) — unclaimed results are otherwise held indefinitely."""
        self._results.pop(ticket, None)

    def project(self, y, levels, radius=1.0, *, method: str | None = None):
        """submit + flush + result in one call (single-request convenience)."""
        ticket = self.submit(y, levels, radius, method=method)
        self.flush()
        return self.result(ticket)
