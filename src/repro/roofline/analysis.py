"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute    = HLO_FLOPs_global / (chips × peak_FLOP/s)
    memory     = HLO_bytes_global / (chips × HBM_bw)
    collective = collective_bytes_global / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` (flops + bytes accessed — reported for
the per-device SPMD module, so ×chips for the global figure);
collective bytes are parsed out of ``compiled.as_text()`` by summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instructions (async *-start ops counted once, ×chips).

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"((?:\([^)]*\)|[\w\[\],{}]+))\s*"                   # result shape
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind bytes (result-shape-based, per device) from HLO text."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind, _start = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(shape_txt)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    chips: int
    coll_breakdown: Dict[str, int]
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""

    def finalize(self):
        self.t_compute = self.flops_global / (self.chips * PEAK_FLOPS)
        self.t_memory = self.bytes_global / (self.chips * HBM_BW)
        self.t_collective = self.coll_bytes_global / (self.chips * LINK_BW)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        return self

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, chips: int, *, hlo_text: Optional[str] = None) -> Roofline:
    """Structural HLO-text cost walk (correct across scan trip counts) —
    see hlo_parse.py. ``compiled.cost_analysis()`` is recorded by the caller
    as a cross-check only (it counts while bodies once)."""
    from . import hlo_parse
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = hlo_parse.analyze_text(text)
    return Roofline(
        flops_global=costs.flops * chips,
        bytes_global=costs.bytes * chips,
        coll_bytes_global=costs.coll_bytes * chips,
        chips=chips,
        coll_breakdown={k: int(v) for k, v in costs.coll_by_kind.items()},
    ).finalize()


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for a train step; 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
