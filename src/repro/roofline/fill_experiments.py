"""Inject generated tables into EXPERIMENTS.md (replaces <!-- X --> markers).

    PYTHONPATH=src python -m repro.roofline.fill_experiments
"""

from __future__ import annotations

import glob
import json
import sys

from .report import _fmt_b, _fmt_t, load, roofline_table


def memory_rows(recs):
    lines = ["| cell | args/dev | temp/dev | fits 16 GB? |",
             "|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        if r["shape"] not in ("train_4k", "decode_32k"):
            continue
        mem = r["memory"]
        tot = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        fits = "✓" if tot <= 16 * 2**30 else f"✗ ({_fmt_b(tot)})"
        lines.append(f"| {r['arch']} × {r['shape']} | "
                     f"{_fmt_b(mem.get('argument_bytes') or 0)} | "
                     f"{_fmt_b(mem.get('temp_bytes') or 0)} | {fits} |")
    return "\n".join(lines)


def perf_table(base_rec, variants, notes):
    """base + variant rows with hypothesis/verdict notes."""
    rf0 = base_rec["roofline"]
    lines = [
        "| variant | compute | memory | collective | Δ dominant | verdict |",
        "|---|---|---|---|---|---|",
        f"| baseline | {_fmt_t(rf0['t_compute'])} | {_fmt_t(rf0['t_memory'])} "
        f"| {_fmt_t(rf0['t_collective'])} | — | (paper-faithful) |",
    ]
    dom = rf0["bottleneck"]
    key = f"t_{dom}"
    for v in variants:
        rf = v["roofline"]
        delta = (rf[key] - rf0[key]) / rf0[key] * 100
        note = notes.get(v["variant"], "")
        lines.append(
            f"| {v['variant']} | {_fmt_t(rf['t_compute'])} | "
            f"{_fmt_t(rf['t_memory'])} | {_fmt_t(rf['t_collective'])} | "
            f"{delta:+.0f}% {dom} | {note} |")
    return "\n".join(lines)


NOTES = {
    "kimi_ep2d": "REFUTED — GSPMD replicates on the (data×model) expert einsum (1 TB temp)",
    "kimi_scatter": "CONFIRMED — K −35%, C −37% (gather dispatch, no one-hot matmul)",
    "kimi_ep2d_scatter": "REFUTED (same GSPMD replication)",
    "kimi_ep2d_scatter_mb32": "REFUTED",
    "kimi_scatter_mb32": "CONFIRMED — K −36%, M −15% (half the FSDP gathers)",
    "kimi_scatter_mb64": "<1% further on K; temp 100 GB/dev — stop",
    "xlstm_chunk128": "−4% M only: state-write ∝1/c but R-matrix streaming dominates",
    "xlstm_chunk256": "flat — refuted as primary lever",
    "xlstm_chunk512": "flat",
    "xlstm_shard_r": "CONFIRMED — M −62%, K −48%: sLSTM R no longer re-streamed whole per step",
    "xlstm_shard_r_chunk128": "CONFIRMED compose — M −67% total vs baseline",
    "xlstm_chunk128_mb64": "K −30% (fewer gathers) but M flat — shard_r superior",
    "stablelm_probsbf16": "REFUTED under cost model (unfused convert penalty; on TPU the Pallas kernel supersedes)",
    "stablelm_chunk2048": "CONFIRMED — M −7% (fewer chunk-scan trips)",
    "stablelm_probsbf16_c2048": "between the two",
    "stablelm_mb64": "REFUTED for M (+5%); K −2%",
    "deepseek_prefill_scatter": "CONFIRMED — C −32%, K −11% (kills one-hot dispatch matmul)",
}


def main():
    recs = load("experiments/dryrun2")
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    hc = {}
    for f in glob.glob("experiments/hillclimb/*.json"):
        v = json.load(open(f))
        hc[v["variant"]] = v

    def cell_variants(prefix):
        return [hc[k] for k in sorted(hc) if k.startswith(prefix)]

    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- DRYRUN_MEMORY -->", memory_rows(recs))
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        roofline_table(recs, "single"))
    for marker, prefix, arch, shape in [
            ("<!-- PERF_KIMI -->", "kimi", "kimi-k2-1t-a32b", "train_4k"),
            ("<!-- PERF_XLSTM -->", "xlstm", "xlstm-1.3b", "train_4k"),
            ("<!-- PERF_STABLELM -->", "stablelm", "stablelm-1.6b", "train_4k")]:
        base = by.get((arch, shape, "single"))
        variants = cell_variants(prefix)
        if base and variants:
            text = text.replace(marker, perf_table(base, variants, NOTES))
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
