"""Structural HLO-text cost model with loop trip-count attribution.

``compiled.cost_analysis()`` counts each while-loop *body* exactly once, which
under-reports every scan-over-layers model by ~L×. This parser rebuilds the
cost from the optimized HLO text instead:

  * computations are parsed into instruction lists with a result-shape symbol
    table (operands in post-opt HLO are bare ``%name`` references);
  * the call graph (while/fusion/call/conditional) is walked from ENTRY with
    multipliers — while bodies/conds inherit ``known_trip_count`` from the
    backend_config;
  * FLOPs: dot ops only — 2 × numel(result) × Πcontracting dims (elementwise
    and transcendental FLOPs are ignored: ≤1% for these architectures);
  * bytes: Σ (operand + result bytes) of top-level instructions in control
    computations (fusion bodies excluded — their internals live in registers;
    the fusion call site contributes its real operand/result buffers). This
    models HBM traffic of a fused TPU executable;
  * collectives: result bytes per op kind; ring all-reduce counted 2×
    (send+receive per device is 2(n-1)/n ≈ 2 of the buffer).

Everything is per-device (post-SPMD); callers multiply by chip count.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e8m0fnu": 1, "f4e2m1fn": 1, "token": 0,
    "opaque": 0, "s2": 1, "u2": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z][\w]*?)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", re.M)
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_NAME = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}]+))\s*([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

# ops that move no data (views / bookkeeping)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "bitcast-convert",
    "opt-barrier",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "ragged-all-to-all",
}
_COLL_DONE = {"all-gather-done", "all-reduce-done", "collective-permute-done"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_TOKEN.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_shape: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # %name -> result shape text
    is_fusion_body: bool = False
    param_gtes: set = dataclasses.field(default_factory=set)  # loop-state views


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    """Split module text into computations. Returns (comps, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        header = _COMP_HEADER.match(line)
        if header and line.rstrip().endswith("{"):
            cur = Computation(header.group(2), [], {})
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            # computation parameters in the header handle their own shapes
            continue
        name, rhs = m.group(1), m.group(2)
        op_m = _OP_NAME.match(rhs)
        if op_m:
            shape_txt, op = op_m.group(1), op_m.group(2)
        else:
            # e.g. "%p = f32[2] parameter(0)" handled above; fallback:
            parts = rhs.split()
            shape_txt, op = parts[0], (parts[1].split("(")[0] if len(parts) > 1
                                       else "unknown")
        # operands: %refs inside the first (...) group after the op name
        paren = rhs[rhs.find("(", len(shape_txt)):]
        depth = 0
        arglist = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arglist += ch
        operands = _OPERANDS.findall(arglist)
        cur.shapes[name] = shape_txt
        cur.instrs.append(Instr(name, op, shape_txt, operands, rhs))
    # mark fusion bodies (referenced via calls= on fusion ops)
    for comp in comps.values():
        params = {i.name for i in comp.instrs if i.op == "parameter"}
        for ins in comp.instrs:
            if ins.op == "fusion":
                for callee in _CALLS.findall(ins.raw):
                    if callee in comps:
                        comps[callee].is_fusion_body = True
            if ins.op == "get-tuple-element" and ins.operands \
                    and (ins.operands[0] in params or not comp.instrs
                         or comp.instrs[0].op == "parameter"):
                comp.param_gtes.add(ins.name)
    # computation parameters: parse "(p0: f32[..], ...)" from headers
    for m2 in re.finditer(
            r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->", hlo, re.M):
        cname, paramtxt = m2.group(1), m2.group(2)
        if cname not in comps:
            continue
        for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}/ ]+))",
                              paramtxt):
            comps[cname].shapes.setdefault(pm.group(1), pm.group(2))
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _shape_dims(ins.result_shape)
    numel = math.prod(out_dims) if out_dims else 0
    lhs_shape = comp.shapes.get(ins.operands[0], "") if ins.operands else ""
    lhs_dims = _shape_dims(lhs_shape)
    cm = _CONTRACT.search(ins.raw)
    contracted = 1
    if cm and lhs_dims:
        for d in cm.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contracted *= lhs_dims[int(d)]
    return 2.0 * numel * contracted


_INPLACE_MIN = 4 << 20  # only alias-credit buffers >= 4 MB


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    """HBM-traffic model for one top-level instruction.

    * dynamic-slice reads only the slice (XLA loop xs indexing);
    * dynamic-update-slice writes only the update (in-place loop ys);
    * a fusion whose result aliases a same-shaped loop-state operand
      (get-tuple-element of the computation parameter) is an in-place
      carry update: the big buffer is not re-streamed each trip.
    """
    res = _shape_bytes(ins.result_shape)
    if ins.op == "dynamic-slice":
        return 2.0 * res  # read slice + write result
    if ins.op == "dynamic-update-slice":
        ups = [_shape_bytes(comp.shapes.get(o, "")) for o in ins.operands[1:]]
        return res and 2.0 * (min(ups) if ups else res)
    total = res
    aliased = False
    for o in ins.operands:
        ob = _shape_bytes(comp.shapes.get(o, ""))
        if (not aliased and ins.op == "fusion" and o in comp.param_gtes
                and comp.shapes.get(o, "") .split("{")[0]
                == ins.result_shape.split("{")[0] and ob >= _INPLACE_MIN):
            aliased = True
            total -= res  # in-place: neither re-read nor re-written in full
            continue
        total += ob
    return max(total, 0.0)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dot_flops_by_shape: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.dot_flops_by_shape.items():
            self.dot_flops_by_shape[k] += v * mult


def analyze_text(hlo: str) -> Costs:
    comps, entry = parse_computations(hlo)
    memo: Dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        comp = comps[name]
        c = Costs()
        memo[name] = c  # guard (HLO computations are acyclic besides while)
        for ins in comp.instrs:
            if ins.op == "dot":
                f = _dot_flops(ins, comp)
                c.flops += f
                c.dot_flops_by_shape[ins.result_shape] += f
            if ins.op in _COLLECTIVES:
                b = _shape_bytes(ins.result_shape)
                kind = ins.op.replace("-start", "")
                factor = 2.0 if kind == "all-reduce" else 1.0
                c.coll_bytes += b * factor
                c.coll_by_kind[kind] += b * factor
            if not comp.is_fusion_body and ins.op not in _FREE_OPS \
                    and ins.op not in _COLL_DONE:
                c.bytes += _instr_bytes(ins, comp)
            # children
            if ins.op == "while":
                tm = _TRIP.search(ins.raw)
                trips = int(tm.group(1)) if tm else 1
                for callee in _CALLS.findall(ins.raw):
                    if callee in comps:
                        c.add(comp_cost(callee), trips)
            elif ins.op in ("fusion", "call", "async-start"):
                for callee in _CALLS.findall(ins.raw):
                    if callee in comps:
                        c.add(comp_cost(callee), 1.0)
            elif ins.op == "conditional":
                bm = _BRANCHES.search(ins.raw)
                names = (_OPERANDS.findall(bm.group(1)) if bm else
                         _CALLS.findall(ins.raw))
                for callee in names:
                    if callee in comps:
                        c.add(comp_cost(callee), 1.0)  # upper bound: any branch
        return c

    if not entry:
        return Costs()
    return comp_cost(entry)
