"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def _fmt_t(seconds):
    if seconds is None:
        return "-"
    return f"{seconds * 1e3:.0f}ms" if seconds < 10 else f"{seconds:.1f}s"


def _fmt_b(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def load(out_dir):
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{out_dir}/*.json"))]
    return sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "HLO TFLOPs | MODEL/HLO | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: sub-quadratic required | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r.get('error','')[:60]} | | | | | | |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        perdev = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        ratio = r.get("useful_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(rf['t_compute'])} | "
            f"{_fmt_t(rf['t_memory'])} | {_fmt_t(rf['t_collective'])} | "
            f"**{rf['bottleneck']}** | {rf['flops_global'] / 1e12:.0f} | "
            f"{ratio:.2f} | {_fmt_b(perdev)} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {_fmt_t(rf['t_compute'])} | "
            f"{_fmt_t(rf['t_memory'])} | {_fmt_t(rf['t_collective'])} | "
            f"**{rf['bottleneck']}** | {rf['flops_global'] / 1e12:.0f} | "
            f"- | {_fmt_b(perdev)} |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | compile | args/dev | temp/dev | "
        "collective schedule (bytes/dev) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP | — | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | — | — | — | {r.get('error','')[:60]} |")
            continue
        mem = r.get("memory", {})
        chips = r.get("chips", 1)
        coll = r["roofline"]["coll_breakdown"]
        coll_s = ", ".join(f"{k}:{_fmt_b(v)}" for k, v in
                           sorted(coll.items(), key=lambda kv: -kv[1]))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.0f}s | "
            f"{_fmt_b((mem.get('argument_bytes') or 0) / chips * chips / chips)} | "
            f"{_fmt_b((mem.get('temp_bytes') or 0) / chips * chips / chips)} | "
            f"{coll_s[:110]} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """The three §Perf cells: worst compute fraction, most collective-bound,
    and the paper-representative train cell."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "single"]

    def frac_compute(r):
        rf = r["roofline"]
        tot = rf["t_compute"] + rf["t_memory"] + rf["t_collective"]
        return rf["t_compute"] / tot if tot else 0

    worst = min(ok, key=frac_compute)
    coll = max(ok, key=lambda r: r["roofline"]["t_collective"])
    return worst, coll


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(out_dir)
    print("## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single pod, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## §Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(recs, "multi"))
    worst, coll = pick_hillclimb(recs)
    print(f"\nworst-compute-fraction cell: {worst['arch']} × {worst['shape']}")
    print(f"most collective-bound cell:  {coll['arch']} × {coll['shape']}")


if __name__ == "__main__":
    main()
