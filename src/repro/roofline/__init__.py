"""repro.roofline — 3-term roofline from compiled dry-run artifacts."""
from .analysis import (  # noqa: F401
    HBM_BW, LINK_BW, PEAK_FLOPS, Roofline, analyze, collective_bytes,
    model_flops,
)
