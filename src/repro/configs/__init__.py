"""repro.configs — architecture registry + config dataclasses."""
from .registry import ARCHS, ASSIGNED, get_arch, smoke_config  # noqa: F401
from .types import (  # noqa: F401
    ArchConfig, HybridConfig, MLAConfig, MoEConfig, ProjectionSpec, SHAPES,
    ShapeConfig, SSMConfig, TrainConfig, XLSTMConfig,
)
