"""--arch whisper-large-v3 — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "whisper-large-v3"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
