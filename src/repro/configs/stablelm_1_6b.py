"""--arch stablelm-1.6b — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "stablelm-1.6b"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
