"""--arch granite-3-2b — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "granite-3-2b"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
