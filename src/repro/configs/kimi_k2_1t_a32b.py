"""--arch kimi-k2-1t-a32b — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "kimi-k2-1t-a32b"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
