"""--arch h2o-danube-1.8b — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "h2o-danube-1.8b"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
