"""Config dataclasses: architectures, shapes, projection specs, training."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # shared expert hidden size (0 -> d_expert)
    first_dense: int = 0          # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    dispatch: str = "einsum"      # "einsum" (GShard) | "scatter" (gather-based)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64             # N (mamba2 state size)
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 P
    chunk: int = 128              # SSD chunk length
    n_groups: int = 1             # B/C groups


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # one sLSTM per this many layers (rest mLSTM)
    chunk: int = 64               # chunkwise-parallel mLSTM chunk length
    proj_factor: float = 2.0      # up-projection in the mLSTM block
    shard_r: bool = False         # TP-shard the sLSTM recurrent matrices
                                  # (output dh over 'model'; §Perf cell B)


@dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6           # one shared attention block per N layers
    shared_attn: bool = True      # Zamba2: ONE weight-shared transformer block
    window_at_long: int = 4096    # window applied to shared attn at >=long_seq
    long_seq: int = 131072


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | encdec | ssm | hybrid | vlm | audio | sae
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window attention
    rope_pct: float = 1.0
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"             # mlp activation
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    n_enc_layers: int = 0         # encoder-decoder only
    enc_frames: int = 1500        # stub audio frontend sequence length
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def params_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6·N·D)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.mla is not None:
            m = self.mla
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_dim + m.qk_rope_dim)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.family in ("ssm",):
            pass  # handled below (xlstm)
        else:
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            per_layer += self.n_heads * hd * d
        if self.family == "ssm" and self.xlstm is not None:
            di = int(d * self.xlstm.proj_factor)
            per_layer = 2 * d * di + 4 * di * di // 4 + di * d  # rough mLSTM block
        if self.family == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * d
            per_layer = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state) + di * d
        # mlp / moe
        mlp = 3 * d * f if f else 0
        n_moe_layers = 0
        if self.moe is not None:
            n_moe_layers = self.n_layers - self.moe.first_dense
            moe_per_layer = self.moe.n_experts * 3 * d * self.moe.d_expert
            moe_per_layer += self.moe.n_shared * 3 * d * (self.moe.d_shared or self.moe.d_expert)
            moe_per_layer += d * self.moe.n_experts  # router
        total = emb + L * per_layer
        if self.moe is not None:
            total += self.moe.first_dense * mlp + n_moe_layers * moe_per_layer
        else:
            total += L * mlp
        if self.n_enc_layers:
            # encoder stack (self-attn + mlp) and decoder cross-attention
            total += self.n_enc_layers * (per_layer + mlp)
            total += L * per_layer
        return int(total)

    def active_params_count(self) -> int:
        """Active (per-token) params — MoE uses top_k + shared experts only."""
        if self.moe is None:
            return self.params_count()
        d = self.d_model
        dense_like = dataclasses.replace(self, moe=None, d_ff=0)
        base = dense_like.params_count()
        n_moe_layers = self.n_layers - self.moe.first_dense
        act_ffn = (self.moe.top_k * 3 * d * self.moe.d_expert
                   + self.moe.n_shared * 3 * d * (self.moe.d_shared or self.moe.d_expert)
                   + d * self.moe.n_experts)
        return int(base + self.moe.first_dense * 3 * d * self.d_ff
                   + n_moe_layers * act_ffn)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ProjectionSpec:
    """The paper's technique attached to training: which params, which norm."""
    pattern: str = r"(w_up|w_gate|w_in)"   # regex over param path
    levels: Tuple[Tuple[object, int], ...] = (("inf", 1), (1, 1))  # bi-level l1inf
    radius: float = 1.0
    every: int = 1                # apply cadence (steps)
    method: str = "bisect"        # l1 solver backend (core.ball registry:
                                  # "sort" | "bisect" | "filter"; bisect =
                                  # kernel/TPU friendly + differentiable,
                                  # filter = linear-time CPU/throughput pick;
                                  # "auto" = autotuned per leaf workload by
                                  # core.plan at hook build time)
    transpose: bool = False       # project the transposed trailing axes
                                  # (groups = rows, e.g. SAE feature selection)
    enabled: bool = True


@dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0           # 0 -> auto (one per data shard)
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 1000
    seed: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    master_dtype: str = "float32"   # "" -> no master copy (params updated in-place)
    moment_dtype: str = "float32"   # "int8" -> block-quantized moments
    grad_allreduce_dtype: str = ""  # "bfloat16" -> compressed cross-replica grads
    remat: bool = True
    projection: Optional[ProjectionSpec] = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
