"""Assigned architectures (verbatim from the assignment table) + the paper's SAE.

Every entry is selectable via ``--arch <id>`` in the launchers, and has a
reduced smoke variant (``smoke_config``) used by the CPU tests.
"""

from __future__ import annotations

import dataclasses

from .types import (ArchConfig, HybridConfig, MLAConfig, MoEConfig, SSMConfig,
                    XLSTMConfig)

_ARCHS = {}


def _register(cfg: ArchConfig) -> ArchConfig:
    _ARCHS[cfg.name] = cfg
    return cfg


STABLELM_1_6B = _register(ArchConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352, rope_pct=0.25,
    notes="[hf:stabilityai/stablelm-2-1_6b] MHA (kv=heads), partial rotary",
))

H2O_DANUBE_1_8B = _register(ArchConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=6912, vocab=32000, window=4096,
    notes="[arXiv:2401.16818] llama+mistral mix, sliding-window attention",
))

GRANITE_3_2B = _register(ArchConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155,
    notes="[hf:ibm-granite/granite-3.0-2b-base] GQA",
))

QWEN3_32B = _register(ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151936, qk_norm=True,
    head_dim=128,
    notes="[hf:Qwen/Qwen3] qk_norm, GQA",
))

WHISPER_LARGE_V3 = _register(ArchConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866, n_enc_layers=32,
    enc_frames=1500, act="gelu", rope_pct=0.0,
    notes="[arXiv:2212.04356] enc-dec; conv frontend is a STUB "
          "(input_specs provides frame embeddings); learned abs positions",
))

DEEPSEEK_V3_671B = _register(ArchConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=18432, vocab=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  d_shared=2048, first_dense=3),
    notes="[arXiv:2412.19437] MLA, 1 shared + 256 routed top-8. MTP head "
          "omitted (training-objective add-on, see DESIGN.md).",
))

KIMI_K2_1T = _register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=18432, vocab=163840,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1,
                  d_shared=2048, first_dense=1),
    notes="[Kimi K2 paper table] trillion-param MoE, 384 routed top-8",
))

CHAMELEON_34B = _register(ArchConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
    notes="[arXiv:2405.09818] early-fusion; VQ image tokens share the vocab, "
          "image frontend is a STUB (tokens arrive pre-quantized)",
))

XLSTM_1_3B = _register(ArchConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8, chunk=64, proj_factor=2.0),
    notes="[arXiv:2405.04517] sLSTM + mLSTM blocks (7:1), no FFN (d_ff=0)",
))

ZAMBA2_7B = _register(ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128,
                  n_groups=2),
    hybrid=HybridConfig(attn_every=6, shared_attn=True, window_at_long=4096,
                        long_seq=131072),
    notes="[arXiv:2411.15242] Mamba2 backbone + ONE weight-shared attn+MLP "
          "block applied every 6 layers (LoRA per-application omitted)",
))

SAE_PAPER = _register(ArchConfig(
    name="sae-paper", family="sae", n_layers=1, d_model=2000, n_heads=1,
    n_kv_heads=1, d_ff=128, vocab=2,
    notes="paper §7.3 supervised autoencoder: d→h→k=classes, symmetric",
))

ARCHS = dict(_ARCHS)
ASSIGNED = [n for n in ARCHS if n != "sae-paper"]


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (shapes only, not capacity)."""
    cfg = get_arch(name)
    kw = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.family != "hybrid" else 7,
        d_model=64, n_heads=4, n_kv_heads=min(4, max(1, cfg.n_kv_heads // 8)),
        d_ff=128 if cfg.d_ff else 0, vocab=256, head_dim=16,
    )
    if cfg.family == "dense" and cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    if cfg.window:
        kw["window"] = 16
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=32, d_shared=32,
            first_dense=min(cfg.moe.first_dense, 1))
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                              qk_rope_dim=8, v_head_dim=16)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_every=4, chunk=8)
        kw["n_layers"] = 8
    if cfg.hybrid:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=3)
        kw["n_layers"] = 7
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
        kw["enc_frames"] = 32
    if cfg.family == "sae":
        kw = dict(n_layers=1, d_model=64, n_heads=1, n_kv_heads=1, d_ff=16,
                  vocab=2, head_dim=0)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
