"""--arch qwen3-32b — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "qwen3-32b"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
