"""--arch sae-paper — see registry.py for the full definition."""

from .registry import get_arch, smoke_config

ARCH_ID = "sae-paper"
CONFIG = get_arch(ARCH_ID)
SMOKE = smoke_config(ARCH_ID)
