"""AdamW from scratch, with optionally int8 block-quantized moments.

The quantized-moment mode is the distributed-optimization trick that makes
Adam states for the 671B/1T MoEs fit a v5e pod: m and v are stored as int8
with a float32 scale per 256-element block of the trailing axis (linear
symmetric for m, linear positive for v). Dequant → f32 update → requant every
step. See EXPERIMENTS.md §Dry-run memory table for the effect.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.types import TrainConfig

_BLOCK = 256


# ------------------------------------------------------------- int8 moments
def _pad_to_block(n: int) -> int:
    return -(-n // _BLOCK) * _BLOCK


def quantize_blockwise(x: jax.Array, signed: bool = True):
    """x (...) f32 -> {'q': int8, 's': f32 scales}; trailing axis blocked."""
    shape = x.shape
    n = shape[-1]
    npad = _pad_to_block(n)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, npad - n)])
    xb = xp.reshape(shape[:-1] + (npad // _BLOCK, _BLOCK))
    if signed:
        s = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    else:
        s = jnp.max(xb, axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(xb / s), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(shape[:-1] + (npad,)),
            "s": s[..., 0].astype(jnp.float32)}


def dequantize_blockwise(qs: Dict[str, jax.Array], n: int) -> jax.Array:
    q, s = qs["q"], qs["s"]
    shape = q.shape
    xb = q.reshape(shape[:-1] + (shape[-1] // _BLOCK, _BLOCK)).astype(jnp.float32)
    x = (xb * s[..., None]).reshape(shape)
    return x[..., :n]


# ------------------------------------------------------------------- schedule
def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------- state
def init(params, cfg: TrainConfig):
    """Optimizer state tree mirroring params."""
    def mom(p):
        if cfg.moment_dtype == "int8":
            z = jnp.zeros(p.shape, jnp.float32)
            return quantize_blockwise(z)
        return jnp.zeros(p.shape, jnp.dtype(cfg.moment_dtype))

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(mom, params),
        "v": jax.tree_util.tree_map(lambda p: mom(p), params),
    }
    if cfg.master_dtype and cfg.master_dtype != cfg.param_dtype:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params)
    return state


def state_specs(param_specs_tree, params_template, cfg: TrainConfig):
    """Specs tree matching init()'s structure."""
    from jax.sharding import PartitionSpec as P
    q = cfg.moment_dtype == "int8"

    def momspec(sp):
        if not q:
            return sp
        # block scales: trailing dim is n_blocks (rarely divisible) -> replicate
        s_spec = P(*(tuple(sp)[:-1] + (None,))) if len(sp) else sp
        return {"q": sp, "s": s_spec}

    mom = jax.tree_util.tree_map(
        momspec, param_specs_tree,
        is_leaf=lambda x: isinstance(x, P))
    out = {"step": P(), "m": mom, "v": mom}
    if cfg.master_dtype and cfg.master_dtype != cfg.param_dtype:
        out["master"] = param_specs_tree
    return out


# --------------------------------------------------------------------- update
def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def grad_clip_factor(grads, cfg: TrainConfig):
    """(gnorm, clip): the global-norm clip multiplier shared by both steps."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    return gnorm, clip


def make_leaf_update(cfg: TrainConfig, step, clip=1.0):
    """Build the per-leaf AdamW update ``one_leaf(g, m, v, p) -> (pnew, m', v')``
    shared by :func:`update` and the fused projected step
    (``optim/fused_step.py``). ``pnew`` comes back in f32 — casting to the
    param/master dtype is the CALLER's epilogue, which is exactly what lets
    the fused step slot the projection in *before* the cast."""
    lr = lr_schedule(step, cfg)
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    quant = cfg.moment_dtype == "int8"

    def one(g, m, v, p):
        gf = g.astype(jnp.float32) * clip
        pf = p.astype(jnp.float32)
        # v is stored int8 in the SQRT domain: linear int8 underflows small
        # second moments inside a block and m/sqrt(v) then explodes.
        mf = dequantize_blockwise(m, p.shape[-1]) if quant else m.astype(jnp.float32)
        vf = dequantize_blockwise(v, p.shape[-1]) ** 2 if quant else v.astype(jnp.float32)
        mf = b1 * mf + (1 - b1) * gf
        vf = b2 * vf + (1 - b2) * gf * gf
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        # decay true matrices only (stacked norm scales (L, d) are exempt)
        if p.ndim >= 2 and min(p.shape[-2:]) >= 64 and cfg.weight_decay:
            upd = upd + cfg.weight_decay * pf
        pnew = pf - lr * upd
        mq = quantize_blockwise(mf) if quant else mf.astype(m.dtype)
        vq = quantize_blockwise(jnp.sqrt(vf), signed=False) if quant \
            else vf.astype(v.dtype)
        return pnew, mq, vq

    def one_leaf(g, m, v, p):
        # layer-stacked tensors update one layer slice at a time (lax.map):
        # bounds the f32 dequant/update working set to a single layer —
        # without this the 671B/1T updates need ~70 GB of f32 temporaries.
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: one(*a), (g, m, v, p))
        return one(g, m, v, p)

    return one_leaf


def update(grads, state, params, cfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm, clip = grad_clip_factor(grads, cfg)
    one_leaf = make_leaf_update(cfg, step, clip)

    quant = cfg.moment_dtype == "int8"
    master = state.get("master")
    src = master if master is not None else params

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"]) if quant else jax.tree_util.tree_leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if quant else jax.tree_util.tree_leaves(state["v"])
    flat_p = jax.tree_util.tree_leaves(src)
    outs = [one_leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_src = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])

    new_state = {"step": step, "m": new_m, "v": new_v}
    if master is not None:
        new_state["master"] = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.dtype(cfg.master_dtype)), new_src)
        new_params = jax.tree_util.tree_map(
            lambda x, p: x.astype(p.dtype), new_src, params)
    else:
        new_params = jax.tree_util.tree_map(
            lambda x, p: x.astype(p.dtype), new_src, params)
    metrics = {"grad_norm": gnorm, "lr": lr_schedule(step, cfg)}
    return new_params, new_state, metrics
