"""The paper's technique as a first-class training feature.

``apply_projection(params, spec, step)`` applies the multi-level projection
(core.multilevel) to every parameter whose path matches ``spec.pattern``,
every ``spec.every`` steps (lax.cond — regex matching is trace-time static).

The projection operates on the TRAILING ``sum(k for _, k in levels)`` axes of
each matched leaf; leading axes ('layers', 'super', 'experts' stacks) are
vmapped — e.g. a stacked MoE weight (L, E, d, f) with bi-level ν projects each
(d, f) expert matrix independently, and ν=((inf,1),(inf,1),(1,1)) projects the
(E, d, f) tensor tri-level per layer (head/expert-structured sparsity, §6 of
the paper).

Passing ``mesh=`` and ``param_specs=`` to :func:`make_projection_hook` makes
the projection *explicitly* mesh-native: every matched leaf whose projected
(trailing) axes are sharded executes the compiled schedule under shard_map in
place — collective reduces of the aggregates, a gathered tiny outer solve,
local applies (DESIGN.md §3) — instead of trusting GSPMD to discover the same
decomposition; leading stacked axes become the executor's batch dims. Leaves
with unsharded trailing axes (or without specs) keep the vmapped single-device
path, which under pjit is still communication-minimal by construction.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.types import ProjectionSpec
from repro.core import ball, multilevel, sharded
from repro.core.masks import sparsity


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _method_resolver(spec: ProjectionSpec):
    """Per-leaf θ-solver resolution, done ONCE per hook (not per step/trace).

    Fixed names validate through the registry immediately; ``"auto"`` is
    resolved per distinct final-level vector length via the planner's
    ``best_l1_method`` (shape-only, so it works while tracing too) and
    memoised — the micro-benchmark runs once per (length, dtype), ever.
    """
    if spec.method != "auto":
        method = ball.resolve_method(spec.method)  # config errors surface once
        return lambda shape, dtype: method

    need = sum(k for _, k in spec.levels)
    cache = {}

    def resolve(shape, dtype):
        trailing = shape[-need:]
        if spec.transpose:
            trailing = tuple(reversed(trailing))
        n_final = multilevel._final_level_size(trailing, spec.levels)
        key = (n_final, np.dtype(dtype).name)
        if key not in cache:
            from repro.core import plan
            cache[key] = plan.best_l1_method(n_final, dtype)
        return cache[key]

    return resolve


def _sharded_leaf_key(mesh, pspec, ndim: int, need: int):
    """The leaf's canonical ShardingKey IF the schedule executor should run
    it: some trailing (projected) axis sharded and the spec
    executor-representable — ``plan.canonical_sharding`` is the single parser
    of spec entries (multi-axis entries like ``("pod", "data")`` make it
    return None → the leaf falls back to the GSPMD path)."""
    if pspec is None:
        return None
    from repro.core import plan as planmod

    key = planmod.canonical_sharding((mesh, pspec), ndim)
    if key is None or not any(n is not None for n in key.spec[ndim - need:]):
        return None
    return key


def _resolve_shard_backend(backend: str, shape, levels, names, mesh, dtype,
                           batch_dims: int) -> str:
    """Pick the shard_map body implementation for one sharded leaf.

    ``"auto"`` lowers the shard-local stages through the fused codegen
    kernels (kernels/codegen/distributed) when the design is eligible and
    the kernels compile natively (TPU); everywhere else — or for designs
    ``shardable`` rejects — it keeps the jnp schedule body, which is the
    same collective plan without the fusion."""
    if backend != "auto":
        return backend
    if jax.default_backend() != "tpu":
        return "jnp"
    from repro.kernels.codegen import distributed as _dist

    try:
        ok = _dist.shardable(shape, list(levels), names, mesh, dtype,
                             batch_dims)
    except Exception:
        ok = False
    return "codegen" if ok else "jnp"


def _project_leaf_sharded(w, spec: ProjectionSpec, radius, method, mesh,
                          names, backend: str = "auto"):
    """Project one sharded leaf in place via the schedule executor: leading
    stacked axes are batch dims, no gather of the weight ever happens.
    ``names`` is the canonical per-axis mesh-axis tuple (ShardingKey.spec)."""
    need = sum(k for _, k in spec.levels)
    batch = w.ndim - need
    if spec.transpose:
        # reverse the trailing (projected) axes — an involution, so the same
        # permutation restores the layout (and permutes the spec with it)
        perm = tuple(range(batch)) + tuple(reversed(range(batch, w.ndim)))
        pnames = tuple(names[a] for a in perm)
        be = _resolve_shard_backend(backend, tuple(w.shape[a] for a in perm),
                                    spec.levels, pnames, mesh, w.dtype, batch)
        kw = {} if be == "jnp" else dict(
            backend="codegen", interpret=jax.default_backend() != "tpu")
        out = sharded.multilevel_project_sharded(
            jnp.transpose(w, perm), list(spec.levels), radius, mesh=mesh,
            spec=P(*pnames), method=method, batch_dims=batch, **kw)
        return jnp.transpose(out, perm)
    be = _resolve_shard_backend(backend, tuple(w.shape), spec.levels, names,
                                mesh, w.dtype, batch)
    kw = {} if be == "jnp" else dict(
        backend="codegen", interpret=jax.default_backend() != "tpu")
    return sharded.multilevel_project_sharded(
        w, list(spec.levels), radius, mesh=mesh, spec=P(*names),
        method=method, batch_dims=batch, **kw)


def _project_leaf(w, levels, radius, method, transpose=False):
    need = sum(k for _, k in levels)

    def core(x):
        if transpose:
            x = jnp.swapaxes(x, 0, -1) if need == 2 else jnp.transpose(
                x, tuple(reversed(range(x.ndim))))
        x = multilevel.multilevel_project(x, list(levels), radius, method)
        if transpose:
            x = jnp.swapaxes(x, 0, -1) if need == 2 else jnp.transpose(
                x, tuple(reversed(range(x.ndim))))
        return x

    fn = core
    for _ in range(w.ndim - need):
        fn = jax.vmap(fn)
    return fn(w)


def _spec_table(param_specs):
    """Flatten a PartitionSpec tree into a path-string → spec lookup."""
    table = {}
    if param_specs is None:
        return table

    def collect(path, s):
        table[_path_str(path)] = s
        return s

    jax.tree_util.tree_map_with_path(collect, param_specs,
                                     is_leaf=lambda x: isinstance(x, P))
    return table


def make_projection_hook(spec: ProjectionSpec | None, *, mesh=None,
                         param_specs=None, backend: str = "auto"):
    """Build the training-time projection hook ONCE (planner lifecycle,
    DESIGN.md §2): compile the regex, validate/resolve the θ-solver backend
    (including ``method="auto"`` via the planner — autotuned per distinct leaf
    workload, memoised forever), and return ``hook(params, step)`` for the
    train step to call every iteration. Per-step/per-trace cost is zero beyond
    the projection itself.

    With ``mesh`` and ``param_specs`` (the params' PartitionSpec tree), every
    matched leaf whose projected trailing axes are sharded runs the schedule
    executor under shard_map in place — no weight gather (DESIGN.md §3).

    ``backend`` selects the shard-local stage implementation for those
    leaves: ``"auto"`` (default) lowers eligible designs through the fused
    codegen kernels on TPU and keeps the jnp schedule body elsewhere;
    ``"jnp"`` / ``"codegen"`` force one — both execute the identical
    collective plan.
    """
    if spec is None or not spec.enabled:
        return lambda params, step: params
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    resolve = _method_resolver(spec)
    specs_by_path = _spec_table(param_specs) if mesh is not None else {}

    def project_all(params):
        def one(path, w):
            name = _path_str(path)
            if w.ndim >= need and pat.search(name):
                method = resolve(w.shape, w.dtype)
                skey = None
                if mesh is not None:
                    skey = _sharded_leaf_key(mesh, specs_by_path.get(name),
                                             w.ndim, need)
                if skey is not None:
                    return _project_leaf_sharded(
                        w, spec, spec.radius, method, mesh, skey.spec,
                        backend=backend,
                    ).astype(w.dtype)
                return _project_leaf(w, spec.levels, spec.radius, method,
                                     transpose=spec.transpose).astype(w.dtype)
            return w

        return jax.tree_util.tree_map_with_path(one, params)

    def hook(params, step):
        if spec.every <= 1:
            return project_all(params)
        return jax.lax.cond(step % spec.every == 0, project_all,
                            lambda p: p, params)

    return hook


def project_tree(params, spec: ProjectionSpec):
    """Unconditionally project matched leaves (jit-safe)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    resolve = _method_resolver(spec)  # config errors surface here once

    def one(path, w):
        name = _path_str(path)
        if w.ndim >= need and pat.search(name):
            return _project_leaf(w, spec.levels, spec.radius,
                                 resolve(w.shape, w.dtype),
                                 transpose=spec.transpose).astype(w.dtype)
        return w

    return jax.tree_util.tree_map_with_path(one, params)


def apply_projection(params, spec: ProjectionSpec, step):
    """Project every ``spec.every`` steps (cheap lax.cond otherwise).

    One-shot form of :func:`make_projection_hook` — prefer the hook in loops
    so the regex/method resolution happens once at build.
    """
    return make_projection_hook(spec)(params, step)


def matched_names(params, spec: ProjectionSpec):
    """Static list of projected parameter paths (for logging/tests)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    names = []

    def one(path, w):
        name = _path_str(path)
        if hasattr(w, "ndim") and w.ndim >= need and pat.search(name):
            names.append(name)
        return w

    jax.tree_util.tree_map_with_path(one, params)
    return names


def tree_sparsity(params, spec: ProjectionSpec):
    """Column-sparsity % of each projected leaf (paper's metric, per tensor)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    out = {}

    def one(path, w):
        name = _path_str(path)
        if w.ndim >= need and pat.search(name):
            out[name] = sparsity(w.reshape(-1, w.shape[-1]), axis=0)
        return w

    jax.tree_util.tree_map_with_path(one, params)
    return out
