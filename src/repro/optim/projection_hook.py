"""The paper's technique as a first-class training feature.

``apply_projection(params, spec, step)`` applies the multi-level projection
(core.multilevel) to every parameter whose path matches ``spec.pattern``,
every ``spec.every`` steps (lax.cond — regex matching is trace-time static).

The projection operates on the TRAILING ``sum(k for _, k in levels)`` axes of
each matched leaf; leading axes ('layers', 'super', 'experts' stacks) are
vmapped — e.g. a stacked MoE weight (L, E, d, f) with bi-level ν projects each
(d, f) expert matrix independently, and ν=((inf,1),(inf,1),(1,1)) projects the
(E, d, f) tensor tri-level per layer (head/expert-structured sparsity, §6 of
the paper).

Under pjit this is communication-minimal by construction (DESIGN.md §3): the
q-norm aggregation reduces the FSDP-sharded axis (one small all-reduce), the
ℓ1 solve runs on the tiny aggregate, the clip is local. core/sharded.py holds
the explicit shard_map variant used by the hillclimb.
"""

from __future__ import annotations

import re
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.types import ProjectionSpec
from repro.core import ball, multilevel
from repro.core.masks import sparsity


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _method_resolver(spec: ProjectionSpec):
    """Per-leaf θ-solver resolution, done ONCE per hook (not per step/trace).

    Fixed names validate through the registry immediately; ``"auto"`` is
    resolved per distinct final-level vector length via the planner's
    ``best_l1_method`` (shape-only, so it works while tracing too) and
    memoised — the micro-benchmark runs once per (length, dtype), ever.
    """
    if spec.method != "auto":
        method = ball.resolve_method(spec.method)  # config errors surface once
        return lambda shape, dtype: method

    need = sum(k for _, k in spec.levels)
    cache = {}

    def resolve(shape, dtype):
        trailing = shape[-need:]
        if spec.transpose:
            trailing = tuple(reversed(trailing))
        n_final = multilevel._final_level_size(trailing, spec.levels)
        key = (n_final, np.dtype(dtype).name)
        if key not in cache:
            from repro.core import plan
            cache[key] = plan.best_l1_method(n_final, dtype)
        return cache[key]

    return resolve


def _project_leaf(w, levels, radius, method, transpose=False):
    need = sum(k for _, k in levels)

    def core(x):
        if transpose:
            x = jnp.swapaxes(x, 0, -1) if need == 2 else jnp.transpose(
                x, tuple(reversed(range(x.ndim))))
        x = multilevel.multilevel_project(x, list(levels), radius, method)
        if transpose:
            x = jnp.swapaxes(x, 0, -1) if need == 2 else jnp.transpose(
                x, tuple(reversed(range(x.ndim))))
        return x

    fn = core
    for _ in range(w.ndim - need):
        fn = jax.vmap(fn)
    return fn(w)


def make_projection_hook(spec: ProjectionSpec | None):
    """Build the training-time projection hook ONCE (planner lifecycle,
    DESIGN.md §2): compile the regex, validate/resolve the θ-solver backend
    (including ``method="auto"`` via the planner — autotuned per distinct leaf
    workload, memoised forever), and return ``hook(params, step)`` for the
    train step to call every iteration. Per-step/per-trace cost is zero beyond
    the projection itself.
    """
    if spec is None or not spec.enabled:
        return lambda params, step: params
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    resolve = _method_resolver(spec)

    def project_all(params):
        def one(path, w):
            name = _path_str(path)
            if w.ndim >= need and pat.search(name):
                method = resolve(w.shape, w.dtype)
                return _project_leaf(w, spec.levels, spec.radius, method,
                                     transpose=spec.transpose).astype(w.dtype)
            return w

        return jax.tree_util.tree_map_with_path(one, params)

    def hook(params, step):
        if spec.every <= 1:
            return project_all(params)
        return jax.lax.cond(step % spec.every == 0, project_all,
                            lambda p: p, params)

    return hook


def project_tree(params, spec: ProjectionSpec):
    """Unconditionally project matched leaves (jit-safe)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    resolve = _method_resolver(spec)  # config errors surface here once

    def one(path, w):
        name = _path_str(path)
        if w.ndim >= need and pat.search(name):
            return _project_leaf(w, spec.levels, spec.radius,
                                 resolve(w.shape, w.dtype),
                                 transpose=spec.transpose).astype(w.dtype)
        return w

    return jax.tree_util.tree_map_with_path(one, params)


def apply_projection(params, spec: ProjectionSpec, step):
    """Project every ``spec.every`` steps (cheap lax.cond otherwise).

    One-shot form of :func:`make_projection_hook` — prefer the hook in loops
    so the regex/method resolution happens once at build.
    """
    return make_projection_hook(spec)(params, step)


def matched_names(params, spec: ProjectionSpec):
    """Static list of projected parameter paths (for logging/tests)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    names = []

    def one(path, w):
        name = _path_str(path)
        if hasattr(w, "ndim") and w.ndim >= need and pat.search(name):
            names.append(name)
        return w

    jax.tree_util.tree_map_with_path(one, params)
    return names


def tree_sparsity(params, spec: ProjectionSpec):
    """Column-sparsity % of each projected leaf (paper's metric, per tensor)."""
    pat = re.compile(spec.pattern)
    need = sum(k for _, k in spec.levels)
    out = {}

    def one(path, w):
        name = _path_str(path)
        if w.ndim >= need and pat.search(name):
            out[name] = sparsity(w.reshape(-1, w.shape[-1]), axis=0)
        return w

    jax.tree_util.tree_map_with_path(one, params)
    return out
