"""repro.optim — AdamW (+ int8 moments), schedules, projection hook."""

from .adamw import (  # noqa: F401
    dequantize_blockwise,
    global_norm,
    init,
    lr_schedule,
    quantize_blockwise,
    state_specs,
    update,
)
from .projection_hook import (  # noqa: F401
    apply_projection,
    make_projection_hook,
    project_tree,
    tree_sparsity,
)
