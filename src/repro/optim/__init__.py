"""repro.optim — AdamW (+ int8 moments), schedules, projection hook."""

from .adamw import (  # noqa: F401
    dequantize_blockwise,
    global_norm,
    init,
    lr_schedule,
    quantize_blockwise,
    state_specs,
    update,
)
from .projection_hook import apply_projection, project_tree, tree_sparsity  # noqa: F401
