"""Fused AdamW-update + multi-level-projection epilogue — one HBM pass.

The unfused projected optimizer is three sweeps over every matched weight:
``adamw.update`` writes p′, the projection hook reads p′ back and writes
Π(p′), and the master-sync reads Π(p′) a third time.  The optimizer epilogue
of LLM training is bandwidth-bound, so :func:`fused_update` does all of it
per leaf in a single pass

    dequant moments → AdamW math (f32) → **project (still f32)** → cast to
    param dtype / master dtype → requant moments

i.e. each matched parameter is read once and written once per direction.
With :func:`make_fused_step`'s ``donate=True`` (the executor donation knob of
``core.plan.make_plan``, applied to the optimizer) XLA reuses the incoming
state/params buffers for the outputs, so peak HBM holds one live copy of the
optimizer state instead of two.

Numerics: the projection acts on the f32 *pre-cast* update — slightly tighter
than the unfused hook, which projects the already-cast params.  On the
f32/no-master path the sequence is operation-for-operation the unfused one
(tests pin parity at 1e-6); the bf16 / int8-moment / master-dtype paths are
pinned by a feasibility property instead: ‖W‖ ≤ radius·(1 + O(eps_dtype))
after every fused step (``tests/test_fused_step.py``).

The θ-solver resolution (including ``method="auto"`` via the planner's
autotuner) reuses the projection hook's resolver, so a fused step and the
standalone hook always agree on backends.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.configs.types import ProjectionSpec, TrainConfig
from repro.optim import adamw
from repro.optim.projection_hook import (_method_resolver, _path_str,
                                         _project_leaf)


def fused_update(grads, state, params, cfg: TrainConfig,
                 spec: ProjectionSpec | None = None):
    """One fused AdamW+project step: ``(new_params, new_state, metrics)``.

    Same contract as :func:`repro.optim.adamw.update`, but every leaf matching
    ``spec.pattern`` is projected onto the multi-level ball BEFORE the
    param/master casts — the fused read-once/write-once epilogue.  ``spec``
    defaults to ``cfg.projection``; a disabled/absent spec degrades to a plain
    AdamW step (same outputs as ``adamw.update``).
    """
    if spec is None:
        spec = cfg.projection
    on = spec is not None and spec.enabled
    step = state["step"] + 1
    gnorm, clip = adamw.grad_clip_factor(grads, cfg)
    one_leaf = adamw.make_leaf_update(cfg, step, clip)

    pat = re.compile(spec.pattern) if on else None
    need = sum(k for _, k in spec.levels) if on else 0
    resolve = _method_resolver(spec) if on else None

    quant = cfg.moment_dtype == "int8"
    master = state.get("master")
    src = master if master is not None else params
    mdtype = jnp.dtype(cfg.master_dtype) if master is not None else None

    flat_pg, treedef = jax.tree_util.tree_flatten_with_path(grads)
    names = [_path_str(p) for p, _ in flat_pg]
    flat_g = [g for _, g in flat_pg]
    flat_m = treedef.flatten_up_to(state["m"]) if quant \
        else jax.tree_util.tree_leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if quant \
        else jax.tree_util.tree_leaves(state["v"])
    flat_src = jax.tree_util.tree_leaves(src)
    flat_prm = jax.tree_util.tree_leaves(params)

    out_p, out_m, out_v, out_ms = [], [], [], []
    for name, g, m, v, ps, p in zip(names, flat_g, flat_m, flat_v,
                                    flat_src, flat_prm):
        pnew, mq, vq = one_leaf(g, m, v, ps)
        if on and pnew.ndim >= need and pat.search(name):
            # materialize the updated leaf once before the projection reads
            # it twice (aggregate reduce + apply): without the barrier XLA
            # fuses the whole update chain into BOTH consumers and computes
            # it twice — costing more than the dispatch the fusion saves
            pnew = jax.lax.optimization_barrier(pnew)
            method = resolve(pnew.shape, pnew.dtype)

            def proj(x, _m=method):
                return _project_leaf(x, spec.levels, spec.radius, _m,
                                     transpose=spec.transpose)

            if spec.every > 1:
                # per-leaf cond: off-cycle steps skip the projection math but
                # keep the single-pass write
                pnew = jax.lax.cond(step % spec.every == 0, proj,
                                    lambda x: x, pnew)
            else:
                pnew = proj(pnew)
        out_p.append(pnew.astype(p.dtype))
        if master is not None:
            out_ms.append(pnew.astype(mdtype))
        out_m.append(mq)
        out_v.append(vq)

    new_state = {"step": step, "m": treedef.unflatten(out_m),
                 "v": treedef.unflatten(out_v)}
    if master is not None:
        new_state["master"] = treedef.unflatten(out_ms)
    metrics = {"grad_norm": gnorm, "lr": adamw.lr_schedule(step, cfg)}
    return treedef.unflatten(out_p), new_state, metrics


def make_fused_step(cfg: TrainConfig, spec: ProjectionSpec | None = None, *,
                    donate: bool = True):
    """Jitted single-dispatch entry ``step(grads, state, params)``.

    ``donate=True`` donates the incoming optimizer state and params (they are
    dead after the step) so XLA writes the outputs in place — the epilogue's
    HBM traffic is then exactly one read + one write of each leaf.
    """
    def step_fn(grads, state, params):
        return fused_update(grads, state, params, cfg, spec)

    if donate:
        return jax.jit(step_fn, donate_argnums=(1, 2))
    return jax.jit(step_fn)
