"""repro.parallel — sharding rule engine (DP/TP/EP/SP over the pod mesh)."""
from .sharding import (  # noqa: F401
    act_rules, batch_axes, batch_spec, cache_spec_tree, dp_shards,
    mesh_shape_dict, named, param_rules, tokens_spec,
)
