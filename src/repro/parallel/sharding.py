"""Sharding rule engine: logical axes → mesh axes, per shape kind.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

Policy (DESIGN.md §6):
  * TP over 'model' : heads / kv_heads / ffn / experts / vocab / ssm_in
  * FSDP over 'data': the 'embed' (d_model) dim of every weight — ZeRO-3-style;
    gathers stream inside the layer scan. Replicated across pods (grads are
    the only cross-pod traffic).
  * batch over ('pod','data'); KV-cache sequence over 'model' (flash-decode).
  * divisibility failures fall back to replication (params.param_specs).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.types import ArchConfig, ShapeConfig


def mesh_shape_dict(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_shards(mesh) -> int:
    shp = mesh_shape_dict(mesh)
    return int(np.prod([shp[a] for a in batch_axes(mesh)]))


def param_rules(mesh, *, fsdp: bool = True) -> Dict[str, object]:
    """logical axis -> mesh axis for parameters."""
    rules = {
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "expert_ff": None,       # experts already consume 'model'
        "experts": "model",
        "vocab": "model",
        "ssm_in": "model",
        "embed": "data" if fsdp else None,
        "layers": None,
        "super": None,
    }
    return rules


def act_rules(mesh, shape: ShapeConfig) -> Dict[str, object]:
    b_ax = batch_axes(mesh)
    b_ax = b_ax[0] if len(b_ax) == 1 else b_ax
    rules = {"batch": b_ax, "cache_seq": "model"}
    return rules


def _shardable(dim: int, axes, shp) -> Optional[object]:
    if axes is None:
        return None
    t = axes if isinstance(axes, tuple) else (axes,)
    size = int(np.prod([shp[a] for a in t]))
    return axes if dim % size == 0 else None


def batch_spec(mesh, global_batch: int, extra_dims: int = 1) -> P:
    """P for (batch, ...) arrays — shards batch over ('pod','data') when it
    divides, over ('data',) as fallback, else replicates (long_500k B=1)."""
    shp = mesh_shape_dict(mesh)
    cand = batch_axes(mesh)
    ax = _shardable(global_batch, cand if len(cand) > 1 else cand[0], shp)
    if ax is None and len(cand) > 1:
        ax = _shardable(global_batch, cand[1], shp)
    return P(ax, *([None] * extra_dims))


def tokens_spec(mesh, shape: ShapeConfig, microbatch: int) -> P:
    """(n_micro, micro_global, seq) training batch."""
    shp = mesh_shape_dict(mesh)
    cand = batch_axes(mesh)
    ax = _shardable(microbatch, cand if len(cand) > 1 else cand[0], shp)
    if ax is None and len(cand) > 1:
        ax = _shardable(microbatch, cand[1], shp)
    return P(None, ax, None)


def cache_spec_tree(cfg: ArchConfig, mesh, cache_tree, shape: ShapeConfig):
    """Specs for a decode cache pytree: batch dim -> data, seq dim -> model.

    Convention per family (see models/*.make_cache):
      leading axis is always the layer stack (replicated);
      4/5-D leaves with a long axis == cache length get seq->model.
    """
    shp = mesh_shape_dict(mesh)
    b = shape.global_batch
    b_ax = batch_axes(mesh)
    b_ax = b_ax if len(b_ax) > 1 else b_ax[0]

    def one(leaf):
        dims = leaf.shape
        parts = [None] * len(dims)
        # find the batch dim: first dim equal to global_batch after the stacks
        for i, dimsz in enumerate(dims):
            if dimsz == b and i >= 1:
                if _shardable(dimsz, b_ax, shp):
                    parts[i] = b_ax
                elif isinstance(b_ax, tuple) and _shardable(dimsz, b_ax[-1], shp):
                    parts[i] = b_ax[-1]
                break
        # seq dim: the dim right after batch when it's >= 1024 (cache length)
        for i in range(1, len(dims)):
            if parts[i - 1] is not None or dims[i - 1] == b:
                if i < len(dims) and dims[i] >= 1024 and dims[i] % shp["model"] == 0:
                    parts[i] = "model"
                break
        # matrix-memory states (mLSTM C: trailing (dk, dv)) — shard dk
        if "model" not in parts and len(dims) >= 2 and dims[-2] >= 512 \
                and dims[-2] % shp["model"] == 0:
            parts[-2] = "model"
        return P(*parts)

    return jax.tree_util.tree_map(one, cache_tree)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
